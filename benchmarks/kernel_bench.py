"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle us/call.

Wall-times on CPU are NOT the perf claim (interpret mode runs the kernel
body in Python); this benchmark validates the call path and records the
oracle cost — the TPU perf story lives in the roofline analysis.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.clip_norm.ops import clip_flat
from repro.kernels.flash_attn.ops import attention
from repro.kernels.randk_gather.ops import gather_rows
from repro.kernels.ssd_scan.ops import ssd_scan


def _time(f, *args, reps=5):
    f(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    d = 128 * 2048
    delta = jax.random.normal(key, (d,))
    idx = jax.random.permutation(key, d // 128)[: d // 128 // 4]
    for use_kernel, tag in ((False, "ref"), (True, "pallas_interp")):
        us = _time(lambda: gather_rows(delta, idx, 1.5,
                                       use_kernel=use_kernel))
        rows.append((f"randk_gather_{tag}", us, f"d={d}"))

    x = 3 * jax.random.normal(key, (d,))
    for use_kernel, tag in ((False, "ref"), (True, "pallas_interp")):
        us = _time(lambda: clip_flat(x, 1.0, use_kernel=use_kernel))
        rows.append((f"clip_norm_{tag}", us, f"d={d}"))

    b, s, h, p, n = 2, 512, 4, 64, 64
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, n)) / 8
    cm = jax.random.normal(ks[4], (b, s, n)) / 8
    for use_kernel, tag in ((False, "ref"), (True, "pallas_interp")):
        us = _time(lambda: ssd_scan(xs, dt, a, bm, cm, chunk=128,
                                    use_kernel=use_kernel), reps=2)
        rows.append((f"ssd_scan_{tag}", us, f"b{b}s{s}h{h}p{p}n{n}"))

    qf = jax.random.normal(key, (1, 512, 8, 64))
    kf = jax.random.normal(key, (1, 512, 2, 64))
    for use_kernel, tag in ((False, "ref"), (True, "pallas_interp")):
        us = _time(lambda: attention(qf, kf, kf, use_kernel=use_kernel),
                   reps=2)
        rows.append((f"flash_attn_{tag}", us, "b1s512h8kv2d64"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    return rows


if __name__ == "__main__":
    run()
