"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle us/call.

Wall-times on CPU are NOT the perf claim (interpret mode runs the kernel
body in Python); this benchmark validates the call path and records the
oracle cost — the TPU perf story lives in the roofline analysis.

``--emit BENCH_6.json`` writes the schema-versioned perf trajectory
(DESIGN.md §12): every row carries its us/call plus — for the PINNED
fused fast-path rows — the us/call of its unfused-oracle counterpart,
so ``tools/check_bench.py`` can gate on fused/oracle RATIOS (machine
speed cancels between the committed trajectory and a fresh CI run).

Forces an 8-device host platform (before jax initializes) so the sharded
cohort round (round_sharded vs round_vmapped rows) actually splits over
devices on CPU. ``benchmarks/run.sh`` is the tuned launcher.
"""
from __future__ import annotations

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import argparse
import fnmatch
import json
import time

import jax
import jax.numpy as jnp

from repro.core import aggregation, randk
from repro.kernels.clip_norm.ops import clip_flat
from repro.kernels.flash_attn.ops import attention
from repro.kernels.pfels_transmit.ops import fused_transmit
from repro.kernels.randk_gather.ops import gather_rows
from repro.kernels.ssd_scan.ops import ssd_scan

# bump when the emitted JSON layout changes; tools/check_bench.py refuses
# to compare trajectories across schema versions
SCHEMA_VERSION = 1

# untimed calls burned before the clock starts (the first triggers
# compilation; extras settle allocator/cache state) — ``--warmup`` flag
DEFAULT_WARMUP = 1


def _time(f, *args, reps=5, warmup=None):
    """us/call of ``f(*args)``: ``warmup`` untimed calls (floored at 1 so
    compilation never lands in the timed region), then ``reps`` timed
    calls on the monotonic high-resolution ``time.perf_counter`` clock
    (``time.time`` is wall-clock: coarse on some platforms and steppable
    by NTP mid-measurement)."""
    w = DEFAULT_WARMUP if warmup is None else warmup
    for _ in range(max(1, w)):
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_pfels_transmit(key, rows, *, r=16, d=128 * 512):
    """Fused transmit pipeline (clip->rand_k->scale->AirComp) vs the
    unfused vmapped-ops path, whole (r, d) batch."""
    k = d // 4
    updates = jax.random.normal(key, (r, d))
    gains = jnp.full((r,), 0.05)
    idx = randk.sample_indices(key, d, k)
    kw = dict(d=d, sigma0=0.3, r=r)

    us = _time(jax.jit(lambda u: aggregation.aircomp_aggregate(
        u, idx, gains, 0.8, key, **kw)), updates)
    rows.append(("pfels_transmit_unfused", us, f"r={r},d={d},k={k}"))
    for use_kernel, tag in ((False, "fused_ref"), (True, "fused_pallas")):
        us = _time(jax.jit(lambda u: fused_transmit(
            u, idx, gains, 0.8, key, use_kernel=use_kernel, **kw)), updates)
        rows.append((f"pfels_transmit_{tag}", us, f"r={r},d={d},k={k}"))


def _fl_problem(cfg):
    """One shared FL benchmark problem (BENCH_MLP on synthetic federated
    data) so every round-driver row measures the same thing."""
    import warnings

    from jax.flatten_util import ravel_pytree

    from repro.configs.paper_models import BENCH_MLP
    from repro.data import make_federated_classification
    from repro.fl import setup
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    d = flat.shape[0]
    x, y, _, _ = make_federated_classification(
        key, n_clients=30, per_client=30, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        st = setup(jax.random.PRNGKey(1), params, cfg, d)
    return params, d, unravel, (x, y), loss_fn, st


def bench_round_drivers(rows, *, t_rounds=8):
    """T rounds, three drivers: python loop over the jitted legacy
    round_fn (one dispatch per round), the legacy lax.scan driver, and
    Trainer.run — the trainer_run-vs-legacy_scan pair demonstrates the new
    API wrapper adds no dispatch overhead over the raw scan."""
    import warnings

    from repro.configs import PFELSConfig
    from repro.fl import Trainer, make_round_fn, make_training_fn
    from repro.fl.api import replace

    cfg = PFELSConfig(num_clients=30, clients_per_round=8, local_steps=3,
                      rounds=t_rounds)
    params, d, unravel, (x, y), loss_fn, st = _fl_problem(cfg)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        fn = make_round_fn(cfg, loss_fn, d, unravel)
        tf = make_training_fn(cfg, loss_fn, d, unravel, rounds=t_rounds)
    keys = jax.random.split(jax.random.PRNGKey(2), t_rounds)

    def loop():
        p = params
        for t in range(t_rounds):
            p, m = fn(p, st.power_limits, x, y, keys[t])
        return p

    us = _time(lambda: jax.tree.leaves(loop())[0], reps=3)
    rows.append(("rounds_python_loop", us, f"T={t_rounds},d={d}"))

    us = _time(lambda: tf(params, st.power_limits, x, y,
                          jax.random.PRNGKey(2))[0], reps=3)
    rows.append(("rounds_legacy_scan", us, f"T={t_rounds},d={d}"))

    trainer = Trainer(cfg, loss_fn, params)
    state = replace(trainer.init(jax.random.PRNGKey(1)),
                    key=jax.random.PRNGKey(2))
    us = _time(lambda: trainer.run(state, x, y,
                                   rounds=t_rounds)[0].prev_delta, reps=3)
    rows.append(("rounds_trainer_run", us,
                 f"T={t_rounds},d={d},ledger=in-graph"))


def bench_bank_backends(rows, *, t_rounds=6):
    """ClientBank backends (DESIGN.md §10), same cfg/key/data: the
    resident scan (dense (n, d) bank in the carry) vs the streamed
    host-driven cohort loop (host bank + prefetched (r, ...) slices).
    The two are bit-identical; this row prices the host round-trips the
    streamed backend pays for device memory independent of n."""
    import dataclasses

    import numpy as np
    from jax.flatten_util import ravel_pytree

    from repro.configs import PFELSConfig
    from repro.configs.paper_models import BENCH_MLP
    from repro.data import make_federated_classification
    from repro.fl import Trainer
    from repro.fl.api import replace
    from repro.models import cnn

    cfg = PFELSConfig(num_clients=200, clients_per_round=8, local_steps=3,
                      error_feedback=True, rounds=t_rounds)
    params = cnn.init_cnn(jax.random.PRNGKey(0), BENCH_MLP)
    d = ravel_pytree(params)[0].shape[0]
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    x, y, _, _ = make_federated_classification(
        jax.random.PRNGKey(0), n_clients=cfg.num_clients, per_client=30,
        num_classes=10, image_shape=(1, 8, 8))

    for backend in ("resident", "streamed"):
        cfg_b = dataclasses.replace(cfg, bank_backend=backend)
        trainer = Trainer(cfg_b, loss_fn, params)
        state = replace(trainer.init(jax.random.PRNGKey(1)),
                        key=jax.random.PRNGKey(2))
        xs = np.asarray(x) if backend == "streamed" else x
        ys = np.asarray(y) if backend == "streamed" else y
        us = _time(lambda: jax.block_until_ready(
            trainer.run(state, xs, ys, rounds=t_rounds)[0].prev_delta),
            reps=3)
        rows.append((f"bank_{backend}", us,
                     f"T={t_rounds},n={cfg.num_clients},"
                     f"r={cfg.clients_per_round},d={d},ef=on"))


def bench_channel_models(rows, *, t_rounds=4):
    """Channel-registry scenarios (DESIGN.md §11), same cfg/key/data via
    Trainer.run: the seed block_fading MAC vs the 8-antenna MRC receiver
    (per-antenna draws + combining + the sqrt(M) noise plumbing) vs
    Gauss–Markov fading (an (n,) latent carried through the scan) — what
    opening the scenario axis costs on the round hot path."""
    import dataclasses

    from repro.configs import ChannelConfig, PFELSConfig
    from repro.fl import Trainer
    from repro.fl.api import replace

    cfg = PFELSConfig(num_clients=30, clients_per_round=8, local_steps=3,
                      rounds=t_rounds)
    params, d, _, (x, y), loss_fn, _ = _fl_problem(cfg)

    for chan, tag in ((ChannelConfig(), "block_fading"),
                      (ChannelConfig(model="mimo_mrc", num_antennas=8),
                       "mimo_mrc[M=8]"),
                      (ChannelConfig(model="markov_fading",
                                     markov_rho=0.9), "markov[rho=.9]")):
        cfg_c = dataclasses.replace(cfg, channel=chan)
        trainer = Trainer(cfg_c, loss_fn, params)
        state = replace(trainer.init(jax.random.PRNGKey(1)),
                        key=jax.random.PRNGKey(2))
        us = _time(lambda: trainer.run(state, x, y,
                                       rounds=t_rounds)[0].prev_delta,
                   reps=3)
        rows.append((f"chan_{tag}", us,
                     f"T={t_rounds},r={cfg.clients_per_round},d={d}"))


def bench_sharded_round(rows):
    """Sharded cohort round (shard_map over ('pod','data'), DESIGN.md §7)
    vs the vmapped single-device round, same cfg and key, via
    Trainer.step."""
    import dataclasses

    from repro.configs import PFELSConfig
    from repro.fl import Trainer
    from repro.fl.api import replace
    from repro.launch.mesh import make_cohort_mesh

    cfg = PFELSConfig(num_clients=30, clients_per_round=8, local_steps=3)
    params, d, _, (x, y), loss_fn, _ = _fl_problem(cfg)
    mesh = make_cohort_mesh(cfg.clients_per_round)
    shards = mesh.shape["pod"] * mesh.shape["data"]

    def _bench(cfg_i, mesh_i):
        trainer = Trainer(cfg_i, loss_fn, params, mesh=mesh_i)
        state = replace(trainer.init(jax.random.PRNGKey(1)),
                        key=jax.random.PRNGKey(2))
        return _time(lambda: trainer.step(state, x, y)[0].prev_delta,
                     reps=3)

    us = _bench(cfg, None)
    rows.append(("round_vmapped", us, f"r={cfg.clients_per_round},d={d}"))

    cfg_s = dataclasses.replace(cfg, client_sharding="cohort")
    us = _bench(cfg_s, mesh)
    rows.append(("round_sharded", us,
                 f"r={cfg.clients_per_round},d={d},shards={shards}"))


# the PR-6 fast-path matrix: every registered channel scenario ×
# execution path gets a fused row and its unfused-oracle twin
_SCENARIOS = (
    ("block_fading", {}),
    ("markov", dict(model="markov_fading", markov_rho=0.9)),
    ("mimo_mrc", dict(model="mimo_mrc", num_antennas=4)),
    ("dropout", dict(model="dropout", dropout_prob=0.4)),
)

# pinned fast-path row -> its unfused-oracle row. Pinned rows are the
# regression surface of the committed trajectory: tools/check_bench.py
# fails if a fresh run's (pinned us)/(oracle us) ratio regresses beyond
# tolerance vs the committed one, or if a pinned row disappears.
PINNED = {
    "pfels_transmit_fused_pallas": "pfels_transmit_unfused",
    **{f"scenario_{tag}_{path}_fused": f"scenario_{tag}_{path}_unfused"
       for tag, _ in _SCENARIOS for path in ("vmapped", "sharded")},
    # ISSUE 7: the compressor hooks (Support.active column, per-client
    # encode, EF residual) must not erode the fused fast path — the
    # carry-compressor row and the encode-hook row each gate their
    # fused/oracle ratio
    "compressor_top_k_ef_fused": "compressor_top_k_ef_unfused",
    "compressor_stoch_quant_fused": "compressor_stoch_quant_unfused",
}

# per-row gate tolerance stamped into the emitted trajectory (overrides
# check_bench's global --tolerance): whole-round Trainer.step timings on a
# shared CI runner jitter far more than isolated kernels, and the
# interpret-mode Pallas row runs its tile loop in Python — both want a
# looser leash. A genuine 2x slowdown (ratio +100%) still fails every row.
ROW_TOLERANCE = {
    "scenario_*": 0.75,
    "compressor_*": 0.75,
    "pfels_transmit_fused_pallas": 0.5,
}


def bench_scenarios(rows):
    """One Trainer.step round per channel model × execution path
    (vmapped / sharded-psum) × {fused default, unfused oracle} — the
    fast-path matrix ISSUE 6 makes the default. The fused rows are the
    pinned perf surface of BENCH_6.json."""
    import dataclasses

    from repro.configs import ChannelConfig, PFELSConfig
    from repro.fl import Trainer
    from repro.fl.api import replace
    from repro.launch.mesh import make_cohort_mesh

    cfg0 = PFELSConfig(num_clients=30, clients_per_round=8, local_steps=2)
    params, d, _, (x, y), loss_fn, _ = _fl_problem(cfg0)
    mesh = make_cohort_mesh(cfg0.clients_per_round)

    for tag, chan_kw in _SCENARIOS:
        chan = ChannelConfig(**chan_kw)
        for path in ("vmapped", "sharded"):
            for fused in (True, False):
                cfg = dataclasses.replace(
                    cfg0, channel=chan, use_fused_kernel=fused,
                    client_sharding="cohort" if path == "sharded"
                    else "none")
                trainer = Trainer(cfg, loss_fn, params,
                                  mesh=mesh if path == "sharded" else None)
                state = replace(trainer.init(jax.random.PRNGKey(1)),
                                key=jax.random.PRNGKey(2))
                us = _time(lambda: trainer.step(state, x, y)[0].prev_delta,
                           reps=2)
                mode = "fused" if fused else "unfused"
                rows.append((f"scenario_{tag}_{path}_{mode}", us,
                             f"r={cfg0.clients_per_round},d={d},"
                             f"chan={chan.model}"))


def bench_compressors(rows):
    """One Trainer.step round per compressor-registry entry (DESIGN.md
    §13) × {fused default, unfused oracle} on the shared FL problem — what
    the Support.active column (threshold), the per-client encode hook
    (stoch_quant), and the carry/EF residual path (top_k_ef) cost on the
    round hot path relative to the seed rand_k round. The top_k_ef and
    stoch_quant fused rows are pinned in the committed trajectory."""
    import dataclasses

    from repro.configs import CompressionSchedule, PFELSConfig
    from repro.fl import Trainer
    from repro.fl.api import replace

    cfg0 = PFELSConfig(num_clients=30, clients_per_round=8, local_steps=2)
    params, d, _, (x, y), loss_fn, _ = _fl_problem(cfg0)

    variants = (
        ("rand_k", dict(compressor="rand_k")),
        ("top_k_ef", dict(compressor="top_k_ef", transmit_clip=0.5)),
        ("threshold", dict(compressor="threshold", threshold_frac=0.3)),
        ("stoch_quant", dict(compressor="stoch_quant", quant_bits=6,
                             transmit_clip=0.5)),
        ("sched_linear", dict(schedule=CompressionSchedule(
            mode="linear", k_end_ratio=0.5))),
    )
    for tag, kw in variants:
        for fused in (True, False):
            cfg = dataclasses.replace(cfg0, use_fused_kernel=fused, **kw)
            trainer = Trainer(cfg, loss_fn, params)
            state = replace(trainer.init(jax.random.PRNGKey(1)),
                            key=jax.random.PRNGKey(2))
            us = _time(lambda: trainer.step(state, x, y)[0].prev_delta,
                       reps=2)
            mode = "fused" if fused else "unfused"
            rows.append((f"compressor_{tag}_{mode}", us,
                         f"r={cfg0.clients_per_round},d={d}"))


def bench_micro(key, rows):
    """Single-op Pallas-vs-ref rows (gather, clip, scan, attention)."""
    d = 128 * 2048
    delta = jax.random.normal(key, (d,))
    idx = jax.random.permutation(key, d // 128)[: d // 128 // 4]
    for use_kernel, tag in ((False, "ref"), (True, "pallas_interp")):
        us = _time(lambda: gather_rows(delta, idx, 1.5,
                                       use_kernel=use_kernel))
        rows.append((f"randk_gather_{tag}", us, f"d={d}"))

    x = 3 * jax.random.normal(key, (d,))
    for use_kernel, tag in ((False, "ref"), (True, "pallas_interp")):
        us = _time(lambda: clip_flat(x, 1.0, use_kernel=use_kernel))
        rows.append((f"clip_norm_{tag}", us, f"d={d}"))

    b, s, h, p, n = 2, 512, 4, 64, 64
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, n)) / 8
    cm = jax.random.normal(ks[4], (b, s, n)) / 8
    for use_kernel, tag in ((False, "ref"), (True, "pallas_interp")):
        us = _time(lambda: ssd_scan(xs, dt, a, bm, cm, chunk=128,
                                    use_kernel=use_kernel), reps=2)
        rows.append((f"ssd_scan_{tag}", us, f"b{b}s{s}h{h}p{p}n{n}"))

    qf = jax.random.normal(key, (1, 512, 8, 64))
    kf = jax.random.normal(key, (1, 512, 2, 64))
    for use_kernel, tag in ((False, "ref"), (True, "pallas_interp")):
        us = _time(lambda: attention(qf, kf, kf, use_kernel=use_kernel),
                   reps=2)
        rows.append((f"flash_attn_{tag}", us, "b1s512h8kv2d64"))


def emit(rows, path):
    """Write the schema-versioned trajectory JSON. Every pinned row must
    have its oracle row in the same run (the gate compares ratios) —
    emitting a partial ``--only`` run that splits a pinned/oracle pair is
    an error, not a silently-gapped trajectory."""
    by_name = {name: us for name, us, _ in rows}
    out = []
    for name, us, cfgstr in rows:
        oracle = PINNED.get(name)
        if oracle is not None and oracle not in by_name:
            raise ValueError(
                f"pinned row {name!r} emitted without its oracle row "
                f"{oracle!r}; widen --only or drop --emit")
        row = {"op": name, "config": cfgstr,
               "us_per_call": round(us, 2),
               "oracle_us_per_call": (round(by_name[oracle], 2)
                                      if oracle else None),
               "pinned": name in PINNED}
        if name in PINNED:
            for pat, tol in ROW_TOLERANCE.items():
                if fnmatch.fnmatch(name, pat):
                    row["tolerance"] = tol
                    break
        out.append(row)
    doc = {"schema_version": SCHEMA_VERSION,
           "meta": {"jax": jax.__version__,
                    "device_count": len(jax.devices()),
                    "platform": jax.devices()[0].platform},
           "rows": out}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(out)} rows -> {path}", flush=True)


def run(only=None):
    key = jax.random.PRNGKey(0)
    rows = []
    groups = (
        ("micro", lambda: bench_micro(key, rows)),
        ("pfels_transmit", lambda: bench_pfels_transmit(key, rows)),
        ("rounds", lambda: bench_round_drivers(rows)),
        ("bank", lambda: bench_bank_backends(rows)),
        ("channels", lambda: bench_channel_models(rows)),
        ("sharded", lambda: bench_sharded_round(rows)),
        ("scenarios", lambda: bench_scenarios(rows)),
        ("compressors", lambda: bench_compressors(rows)),
    )
    for name, fn in groups:
        if only and not any(fnmatch.fnmatch(name, p) for p in only):
            continue
        fn()

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    return rows


def main(argv=None):
    global DEFAULT_WARMUP
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--emit", default=None, metavar="PATH",
                    help="also write the schema-versioned trajectory JSON "
                         "(e.g. benchmarks/BENCH_6.json)")
    ap.add_argument("--warmup", type=int, default=None,
                    help=f"untimed warmup calls per row (default "
                         f"{DEFAULT_WARMUP}; floored at 1 so compile "
                         f"never pollutes the timed region)")
    ap.add_argument("--only", default=None,
                    help="comma-separated fnmatch pattern(s) of bench "
                         "groups to run (micro, pfels_transmit, rounds, "
                         "bank, channels, sharded, scenarios, "
                         "compressors)")
    args = ap.parse_args(argv)
    if args.warmup is not None:
        DEFAULT_WARMUP = args.warmup
    rows = run(only=args.only.split(",") if args.only else None)
    if args.emit:
        emit(rows, args.emit)
    return 0


if __name__ == "__main__":
    main()
