"""Roofline table from the dry-run records (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and prints, per (arch x shape), the three
roofline terms, the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os


def load_records(out_dir="experiments/dryrun", tag="pod"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{tag}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def write_markdown(recs, path="experiments/roofline_table.md"):
    lines = [
        "# Roofline — single-pod 16x16 (256 chips), baseline configs",
        "",
        "| arch | shape | t_compute (ms) | t_memory (ms) | t_collective "
        "(ms) | dominant | useful | mem/dev (GiB) |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in recs:
        t = r["roofline"]
        gb = r["memory"]["peak_bytes_per_device"] / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute_s']*1e3:.2f} "
            f"| {t['t_memory_s']*1e3:.2f} | {t['t_collective_s']*1e3:.2f} "
            f"| {t['dominant']} | {r['useful_flops_ratio']:.2f} "
            f"| {gb:.2f} |")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


def run(out_dir="experiments/dryrun", tag="pod"):
    recs = load_records(out_dir, tag)
    rows = []
    hdr = (f"{'arch':22s} {'shape':12s} {'t_comp_ms':>10s} {'t_mem_ms':>10s}"
           f" {'t_coll_ms':>10s} {'dom':>10s} {'useful':>7s} {'mem/dev':>8s}")
    print(hdr)
    for r in recs:
        t = r["roofline"]
        gb = r["memory"]["peak_bytes_per_device"] / 2 ** 30
        line = (f"{r['arch']:22s} {r['shape']:12s}"
                f" {t['t_compute_s']*1e3:10.2f} {t['t_memory_s']*1e3:10.2f}"
                f" {t['t_collective_s']*1e3:10.2f} {t['dominant']:>10s}"
                f" {r['useful_flops_ratio']:7.2f} {gb:7.2f}G")
        print(line)
        rows.append((f"roofline_{r['arch']}_{r['shape']}",
                     t["t_compute_s"] * 1e6,
                     f"dom={t['dominant']};useful="
                     f"{r['useful_flops_ratio']:.2f}"))
    if recs:
        try:
            write_markdown(recs)
        except OSError:
            pass
    return rows


if __name__ == "__main__":
    run()
