"""Benchmark orchestrator — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds/seeds (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,table2,fig5,fig7,beyond,"
                         "population,kernels,roofline")
    args = ap.parse_args()

    from benchmarks import (beyond_paper, fig3_compression,
                            fig4_privacy_accuracy, fig5_comm, fig7_energy,
                            kernel_bench, population_scale, roofline,
                            table2_summary)

    rounds = 12 if args.quick else 30
    seeds = (0,) if args.quick else (0, 1, 2)
    jobs = {
        "fig3": lambda: fig3_compression.run(rounds=rounds, seeds=seeds),
        "fig4": lambda: fig4_privacy_accuracy.run(
            rounds=rounds, seeds=seeds[:2] if len(seeds) > 1 else seeds),
        "table2": lambda: table2_summary.run(rounds=rounds, seeds=seeds),
        "fig5": lambda: fig5_comm.run(rounds=rounds),
        "fig7": lambda: fig7_energy.run(rounds=rounds),
        "beyond": lambda: beyond_paper.run(rounds=rounds),
        "population": lambda: population_scale.run(quick=args.quick),
        "kernels": kernel_bench.run,
        "roofline": roofline.run,
    }
    only = args.only.split(",") if args.only else list(jobs)
    rows = []
    for name in only:
        print(f"== {name} ==", flush=True)
        try:
            rows.extend(jobs[name]())
        except FileNotFoundError as e:  # roofline before dry-run
            print(f"skipped {name}: {e}", file=sys.stderr)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
