"""Paper Fig. 5/6: training progress vs COMMUNICATION COST (cumulative
subcarrier uses) for PFELS vs baselines.

Claim reproduced: at equal communication budget, PFELS reaches higher
accuracy — each PFELS round costs k = p*d subcarriers vs d for the
full-update baselines.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import build_problem, make_trainer
from repro.fl.api import replace


def run(rounds=60, eps=1.5, p=0.3, comm_budget_factor=0.5):
    """comm budget = factor * (rounds * d) subcarriers."""
    problem = build_problem()
    d = problem[1]
    x, y, xt, yt = problem[3]
    budget = comm_budget_factor * rounds * d
    rows = []
    for alg in ("pfels", "wfl_p", "wfl_pdp"):
        trainer, state = make_trainer(alg, problem, rounds=rounds, p=p,
                                      eps=eps)
        state = replace(state, key=jax.random.PRNGKey(5000))
        comm = 0.0
        t0 = time.time()
        while comm < budget and int(state.round) < rounds * 4:
            state, m = trainer.step(state, x, y)
            comm += float(m["subcarriers"])
        t = int(state.round)
        _, acc = trainer.evaluate(state, xt, yt)
        us = (time.time() - t0) / max(t, 1) * 1e6
        print(f"fig5 {alg:8s} comm={comm:.2e} rounds={t} acc={acc:.3f}",
              flush=True)
        rows.append((f"fig5_{alg}", us,
                     f"comm={comm:.3e};rounds={t};acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    run()
