"""Paper Fig. 5/6: training progress vs COMMUNICATION COST (cumulative
subcarrier uses) for PFELS vs baselines.

Claim reproduced: at equal communication budget, PFELS reaches higher
accuracy — each PFELS round costs k = p*d subcarriers vs d for the
full-update baselines.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import build_problem, scaled_channel
from repro.configs import PFELSConfig
from repro.fl import evaluate, make_round_fn, setup


def run(rounds=60, eps=1.5, p=0.3, comm_budget_factor=0.5):
    """comm budget = factor * (rounds * d) subcarriers."""
    params, d, unravel, (x, y, xt, yt), loss_fn = build_problem()
    budget = comm_budget_factor * rounds * d
    rows = []
    for alg in ("pfels", "wfl_p", "wfl_pdp"):
        cfg = PFELSConfig(num_clients=60, clients_per_round=8,
                          local_steps=5, local_lr=0.05,
                          compression_ratio=p, epsilon=eps,
                          rounds=rounds, momentum=0.9, algorithm=alg,
                          channel=scaled_channel(d))
        state = setup(jax.random.PRNGKey(1), params, cfg, d)
        fn = make_round_fn(cfg, loss_fn, d, unravel)
        pm, comm = params, 0.0
        t0 = time.time()
        t = 0
        while comm < budget and t < rounds * 4:
            pm, m = fn(pm, state.power_limits, x, y,
                       jax.random.PRNGKey(5000 + t))
            comm += float(m["subcarriers"])
            t += 1
        _, acc = evaluate(pm, loss_fn, xt, yt)
        us = (time.time() - t0) / max(t, 1) * 1e6
        print(f"fig5 {alg:8s} comm={comm:.2e} rounds={t} acc={acc:.3f}",
              flush=True)
        rows.append((f"fig5_{alg}", us,
                     f"comm={comm:.3e};rounds={t};acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    run()
