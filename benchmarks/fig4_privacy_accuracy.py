"""Paper Fig. 4: test accuracy vs privacy budget eps for PFELS vs WFL-P /
WFL-PDP / DP-FedAvg.

Claims reproduced: (i) accuracy increases with eps for the DP schemes;
(ii) PFELS >= WFL-PDP at the same eps; (iii) WFL-P upper-bounds WFL-PDP.
"""
from __future__ import annotations

from benchmarks.common import build_problem, run_fl

EPS_GRID = (0.5, 1.0, 2.0, 4.0)


def run(rounds=40, seeds=(0, 1)):
    problem = build_problem()
    rows = []
    base = run_fl("wfl_p", rounds=rounds, seeds=seeds, problem=problem)
    rows.append(("fig4_wfl_p", base["us_per_round"],
                 f"acc={base['accuracy']:.3f}"))
    print(f"fig4 wfl_p acc={base['accuracy']:.3f}", flush=True)
    for eps in EPS_GRID:
        for alg in ("pfels", "wfl_pdp", "dp_fedavg"):
            r = run_fl(alg, rounds=rounds, eps=eps, seeds=seeds,
                       problem=problem)
            rows.append((f"fig4_{alg}_eps{eps}", r["us_per_round"],
                         f"acc={r['accuracy']:.3f}"))
            print(f"fig4 {alg} eps={eps} acc={r['accuracy']:.3f}",
                  flush=True)
    return rows


if __name__ == "__main__":
    run()
