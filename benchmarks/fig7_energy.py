"""Paper Fig. 7: cumulative transmit energy vs accuracy trajectory.

Claim reproduced: PFELS reaches a given accuracy with less cumulative
transmit energy than WFL-P / WFL-PDP.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import build_problem, make_trainer
from repro.fl.api import replace


def run(rounds=40, eps=1.5):
    problem = build_problem()
    x, y, xt, yt = problem[3]
    rows = []
    for alg in ("pfels", "wfl_p", "wfl_pdp"):
        trainer, state = make_trainer(alg, problem, rounds=rounds, eps=eps)
        state = replace(state, key=jax.random.PRNGKey(7000))
        t0 = time.time()
        state, m = trainer.run(state, x, y, rounds=rounds)
        jax.block_until_ready(state.params)
        energy = float(m["energy"].sum())
        _, acc = trainer.evaluate(state, xt, yt)
        us = (time.time() - t0) / rounds * 1e6
        print(f"fig7 {alg:8s} energy={energy:.3e} acc={acc:.3f}",
              flush=True)
        rows.append((f"fig7_{alg}", us,
                     f"energy={energy:.3e};acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    run()
