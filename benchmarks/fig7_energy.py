"""Paper Fig. 7: cumulative transmit energy vs accuracy trajectory.

Claim reproduced: PFELS reaches a given accuracy with less cumulative
transmit energy than WFL-P / WFL-PDP.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import build_problem, scaled_channel
from repro.configs import PFELSConfig
from repro.fl import evaluate, make_round_fn, setup


def run(rounds=40, eps=1.5):
    params, d, unravel, (x, y, xt, yt), loss_fn = build_problem()
    rows = []
    for alg in ("pfels", "wfl_p", "wfl_pdp"):
        cfg = PFELSConfig(num_clients=60, clients_per_round=8,
                          local_steps=5, local_lr=0.05,
                          compression_ratio=0.3, epsilon=eps,
                          rounds=rounds, momentum=0.9, algorithm=alg,
                          channel=scaled_channel(d))
        state = setup(jax.random.PRNGKey(1), params, cfg, d)
        fn = make_round_fn(cfg, loss_fn, d, unravel)
        pm, energy = params, 0.0
        t0 = time.time()
        for t in range(rounds):
            pm, m = fn(pm, state.power_limits, x, y,
                       jax.random.PRNGKey(7000 + t))
            energy += float(m["energy"])
        _, acc = evaluate(pm, loss_fn, xt, yt)
        us = (time.time() - t0) / rounds * 1e6
        print(f"fig7 {alg:8s} energy={energy:.3e} acc={acc:.3f}",
              flush=True)
        rows.append((f"fig7_{alg}", us,
                     f"energy={energy:.3e};acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    run()
