"""Beyond-paper ablations (the paper's own future-work items):

1. Imperfect CSI (paper §9 defers this): accuracy vs gain-estimation error.
2. Server-guided top-k vs rand_k compression (paper §9 "other compression
   methods"): top-k of |Delta_hat_{t-1}| keeps the shared-subcarrier
   alignment AirComp requires while concentrating the budget on the
   highest-energy coordinates.
3. Error feedback [28-30] on top of rand_k.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import build_problem, make_trainer, scaled_channel
from repro.fl.api import replace


def _run_variant(problem, *, rounds=30, eps=1.0, p=0.3, seed=0, **kw):
    """One Trainer.run call: the error-feedback memory and the server_topk
    support (TrainState.residuals / .prev_delta) carry inside the compiled
    state — no more per-config hand-threading of residuals and the
    metrics-smuggled delta_hat."""
    x, y, xt, yt = problem[3]
    trainer, state = make_trainer("pfels", problem, rounds=rounds, p=p,
                                  eps=eps, **kw)
    state = replace(state, key=jax.random.PRNGKey(seed * 999))
    t0 = time.time()
    state, _ = trainer.run(state, x, y, rounds=rounds)
    jax.block_until_ready(state.params)
    _, acc = trainer.evaluate(state, xt, yt)
    return acc, (time.time() - t0) / rounds * 1e6


def run(rounds=30):
    problem = build_problem()
    d = problem[1]
    rows = []

    # 1) imperfect CSI sweep
    for err in (0.0, 0.05, 0.1, 0.2):
        base = scaled_channel(d)
        chan = dataclasses.replace(base, csi_error=err)
        acc, us = _run_variant(problem, rounds=rounds, channel=chan)
        print(f"beyond csi_err={err:.2f} acc={acc:.3f}", flush=True)
        rows.append((f"beyond_csi{err}", us, f"acc={acc:.3f}"))

    # 2) compression method ablation at tight budget
    for mode, ef in (("exact", False), ("server_topk", False),
                     ("exact", True)):
        acc, us = _run_variant(problem, rounds=rounds, p=0.1, eps=1.0,
                               randk_mode=mode, error_feedback=ef)
        tag = f"{mode}{'+ef' if ef else ''}"
        print(f"beyond compression={tag} (p=0.1) acc={acc:.3f}", flush=True)
        rows.append((f"beyond_{tag}", us, f"acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    run()
