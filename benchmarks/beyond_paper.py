"""Beyond-paper ablations (the paper's own future-work items):

1. Imperfect CSI (paper §9 defers this): accuracy vs gain-estimation error.
2. Server-guided top-k vs rand_k compression (paper §9 "other compression
   methods"): top-k of |Delta_hat_{t-1}| keeps the shared-subcarrier
   alignment AirComp requires while concentrating the budget on the
   highest-energy coordinates.
3. Error feedback [28-30] on top of rand_k.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import build_problem, scaled_channel
from repro.configs import PFELSConfig
from repro.fl import evaluate, make_round_fn, setup


def _run_variant(problem, *, rounds=30, eps=1.0, p=0.3, seed=0, **kw):
    params, d, unravel, (x, y, xt, yt), loss_fn = problem
    chan = kw.pop("channel", None) or scaled_channel(d)
    cfg = PFELSConfig(num_clients=60, clients_per_round=8, local_steps=5,
                      local_lr=0.05, compression_ratio=p, epsilon=eps,
                      rounds=rounds, momentum=0.9, channel=chan, **kw)
    state = setup(jax.random.PRNGKey(1), params, cfg, d)
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    pm = params
    res = state.residuals
    prev = jnp.zeros((d,)) if cfg.randk_mode == "server_topk" else None
    t0 = time.time()
    for t in range(rounds):
        key = jax.random.PRNGKey(seed * 999 + t)
        if cfg.error_feedback:
            pm, m, res = fn(pm, state.power_limits, x, y, key, res, prev)
        else:
            pm, m = fn(pm, state.power_limits, x, y, key, None, prev)
        if prev is not None:
            prev = m["delta_hat"]
    _, acc = evaluate(pm, loss_fn, xt, yt)
    return acc, (time.time() - t0) / rounds * 1e6


def run(rounds=30):
    problem = build_problem()
    d = problem[1]
    rows = []

    # 1) imperfect CSI sweep
    for err in (0.0, 0.05, 0.1, 0.2):
        base = scaled_channel(d)
        chan = dataclasses.replace(base, csi_error=err)
        acc, us = _run_variant(problem, rounds=rounds, channel=chan)
        print(f"beyond csi_err={err:.2f} acc={acc:.3f}", flush=True)
        rows.append((f"beyond_csi{err}", us, f"acc={acc:.3f}"))

    # 2) compression method ablation at tight budget
    for mode, ef in (("exact", False), ("server_topk", False),
                     ("exact", True)):
        acc, us = _run_variant(problem, rounds=rounds, p=0.1, eps=1.0,
                               randk_mode=mode, error_feedback=ef)
        tag = f"{mode}{'+ef' if ef else ''}"
        print(f"beyond compression={tag} (p=0.1) acc={acc:.3f}", flush=True)
        rows.append((f"beyond_{tag}", us, f"acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    run()
