#!/usr/bin/env bash
# Tuned launcher for the kernel benchmark (DESIGN.md §12).
#
# Pins the runtime knobs that otherwise make bench numbers incomparable
# run-to-run, then forwards every argument to kernel_bench:
#
#   benchmarks/run.sh                                   # print CSV rows
#   benchmarks/run.sh --emit benchmarks/BENCH_6.json    # + trajectory JSON
#   benchmarks/run.sh --only scenarios --warmup 3
#
# Knobs (idioms documented in SNIPPETS.md):
#  - tcmalloc preload (when present): glibc malloc contention skews the
#    host-loop rows; skipped silently if the lib is not installed.
#  - --xla_force_host_platform_device_count=8: the sharded rows must
#    split over 8 host devices, set before jax initializes.
#  - --xla_cpu_enable_fast_math=false: keep timed numerics identical to
#    the test numerics (no fast-math-only speedups in the trajectory).
#  - step-marker at entry so per-step boundaries survive into profiles.
#  - JAX_DEFAULT_DTYPE_BITS=32 + no-x64: the fp32 dtype policy the repro
#    trains under; benching fp64 paths would gate the wrong kernels.
#  - TF_CPP_MIN_LOG_LEVEL=4: log spam perturbs timings via stderr I/O.
set -euo pipefail

cd "$(dirname "$0")/.."

for lib in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
           /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4; do
  if [[ -z "${LD_PRELOAD:-}" && -e "$lib" ]]; then
    export LD_PRELOAD="$lib"
    break
  fi
done

export TF_CPP_MIN_LOG_LEVEL=4
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
export JAX_ENABLE_X64=0
export JAX_DEFAULT_DTYPE_BITS=32
# step marker at the outer while loop = the round scan (entry would mark
# whole-program dispatch instead)
export XLA_FLAGS="${XLA_FLAGS:-} \
  --xla_force_host_platform_device_count=8 \
  --xla_cpu_enable_fast_math=false \
  --xla_step_marker_location=STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python benchmarks/kernel_bench.py "$@"
