"""Population-scale ClientBank benchmark (DESIGN.md §10).

Trains PFELS with ``bank_backend="streamed"`` at ``num_clients=100_000``
— the Alg. 2 line 2 regime (r sampled from a large n) that the resident
design could never reach — and PROVES the memory contract: during the
whole run no ``(n, d)`` or ``(n, samples, ...)`` array may exist on
device (the EF residual bank lives host-side; cohort slices stream
through donated ``(r, d)`` buffers). Device-resident state is checked by
walking ``jax.live_arrays()`` after training: any array with a leading
population dim of rank >= 2 fails the run. Only ``(n,)`` vectors (power
limits) may scale with n.

Rows: one per population size, ``us_per_round`` wall time with the peak
device-byte census in the derived column — device bytes must be ~flat in
n while n grows 10x.

  PYTHONPATH=src python -m benchmarks.population_scale [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import CNNConfig, PFELSConfig
from repro.core.channel import scaled_channel
from repro.data import make_population_source
from repro.fl import Trainer
from repro.models import cnn

# tiny MLP (d ~ 700): population scale is about n, not d — the host-side
# (n, d) residual bank at n=100_000 stays ~300 MB
POP_MLP = CNNConfig(name="pop-mlp", arch="mlp", in_channels=1,
                    image_size=4, num_classes=10, width_mult=0.125,
                    source="tiny MLP for population-scale bank runs")


def device_census(n_clients: int):
    """(total_bytes, offenders): all live device arrays, and those whose
    leading dim is the population size with rank >= 2 — the arrays the
    streamed bank contract forbids."""
    total, offenders = 0, []
    for a in jax.live_arrays():
        total += a.size * a.dtype.itemsize
        if a.ndim >= 2 and a.shape[0] == n_clients:
            offenders.append(tuple(a.shape))
    return total, offenders


def train_population(n_clients: int, *, rounds: int = 4, r: int = 16,
                     per_client: int = 10, seed: int = 0):
    """One streamed run at population n; returns (us_per_round, stats)."""
    key = jax.random.PRNGKey(seed)
    params = cnn.init_cnn(key, POP_MLP)
    d = sum(p.size for p in jax.tree.leaves(params))
    cfg = PFELSConfig(
        num_clients=n_clients, clients_per_round=r, local_steps=2,
        local_lr=0.05, compression_ratio=0.3, epsilon=2.0, rounds=rounds,
        error_feedback=True, bank_backend="streamed",
        channel=scaled_channel(d))
    source, xt, yt = make_population_source(
        key, n_clients=n_clients, per_client=per_client,
        num_classes=POP_MLP.num_classes,
        image_shape=(POP_MLP.in_channels, POP_MLP.image_size,
                     POP_MLP.image_size))
    loss_fn = lambda p, b: cnn.cnn_loss(p, POP_MLP, b)
    trainer = Trainer(cfg, loss_fn, params)
    state = trainer.init(key)

    state, m = trainer.run(state, source, rounds=1)      # compile round
    t0 = time.time()
    state, m = trainer.run(state, source, rounds=rounds)
    jax.block_until_ready(state.params)
    us = (time.time() - t0) / rounds * 1e6

    total, offenders = device_census(n_clients)
    if offenders:
        raise AssertionError(
            f"population tensors leaked onto device at n={n_clients}: "
            f"{offenders} (streamed-bank contract, DESIGN.md §10)")
    assert np.isfinite(np.asarray(m["train_loss"])).all()
    assert int(state.bank.counts.sum()) == (rounds + 1) * r
    assert state.bank.residuals.shape == (n_clients, d)   # host-side
    stats = {"d": d, "device_mb": total / 1e6,
             "loss": float(np.asarray(m["train_loss"])[-1])}
    return us, stats


def run(quick: bool = False, smoke: bool = False):
    sizes = (2_000, 10_000) if (quick or smoke) else (10_000, 100_000)
    rounds = 2 if (quick or smoke) else 4
    rows = []
    for n in sizes:
        us, s = train_population(n, rounds=rounds)
        print(f"population n={n}: {us:.0f} us/round, "
              f"device={s['device_mb']:.1f} MB, d={s['d']}", flush=True)
        rows.append((f"population_scale_n{n}", us,
                     f"d={s['d']},device_mb={s['device_mb']:.1f},"
                     f"loss={s['loss']:.3f}"))
    # the headline claim: device bytes flat while n grows
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size populations (2k/10k, 2 rounds)")
    args = ap.parse_args()
    run(smoke=args.smoke)
