"""Paper Fig. 3: test accuracy vs compression ratio p for PFELS.

Claim reproduced: accuracy first rises (compression error shrinks) then
falls (privacy error grows) as p sweeps 0.1 -> 1.0.
"""
from __future__ import annotations

from benchmarks.common import build_problem, run_fl

P_GRID = (0.1, 0.3, 0.5, 0.8, 1.0)


def run(rounds=30, eps=0.4, seeds=(0, 1, 2)):
    problem = build_problem()
    rows = []
    for p in P_GRID:
        r = run_fl("pfels", rounds=rounds, p=p, eps=eps, seeds=seeds,
                   problem=problem)
        rows.append((f"fig3_p{p}", r["us_per_round"],
                     f"acc={r['accuracy']:.3f}"))
        print(f"fig3 p={p:.1f} acc={r['accuracy']:.3f}", flush=True)
    return rows


if __name__ == "__main__":
    run()
