"""Shared FL-benchmark harness (CPU-scale reproduction of the paper's
experimental protocol, DESIGN.md §2)."""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs import ChannelConfig, PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.data import make_federated_classification
from repro.fl import evaluate, make_round_fn, setup
from repro.models import cnn

PAPER_D = 9_750_922  # paper's VGG-11 dimension


def scaled_channel(d: int) -> ChannelConfig:
    """The power cap floor is beta_min ~ gain_min * sqrt(d) * sqrt(SNR)
    (Eq. 34c with P = SNR*d*sigma0^2). Reproducing the paper's REGIME at a
    reduced model dimension therefore requires scaling the fading floor by
    sqrt(d_paper/d); otherwise worst-channel rounds inject catastrophically
    larger relative noise than the paper ever sees."""
    floor = 1e-4 * math.sqrt(PAPER_D / d)
    return ChannelConfig(gain_clip=(min(floor, 0.05), 0.1))


def build_problem(seed=0, n_clients=60, per_client=40, model_cfg=BENCH_MLP):
    key = jax.random.PRNGKey(seed)
    params = cnn.init_cnn(key, model_cfg)
    flat, unravel = ravel_pytree(params)
    data = make_federated_classification(
        key, n_clients=n_clients, per_client=per_client,
        num_classes=model_cfg.num_classes,
        image_shape=(model_cfg.in_channels, model_cfg.image_size,
                     model_cfg.image_size), noise=1.4)
    loss_fn = lambda p, b: cnn.cnn_loss(p, model_cfg, b)
    return params, flat.shape[0], unravel, data, loss_fn


def run_fl(alg: str, *, rounds=40, p=0.3, eps=1.5, seeds=(0, 1, 2),
           n_clients=60, r=8, tau=5, lr=0.05, problem=None,
           dp_sigma=1.0):
    """Returns dict with mean final accuracy, energy, subcarriers, and
    us_per_round."""
    accs, energies, subs, times = [], [], [], []
    for seed in seeds:
        params, d, unravel, (x, y, xt, yt), loss_fn = \
            problem or build_problem(seed=0, n_clients=n_clients)
        cfg = PFELSConfig(num_clients=n_clients, clients_per_round=r,
                          local_steps=tau, local_lr=lr,
                          compression_ratio=p, epsilon=eps, rounds=rounds,
                          momentum=0.9, algorithm=alg,
                          dp_fedavg_sigma=dp_sigma,
                          channel=scaled_channel(d))
        state = setup(jax.random.PRNGKey(1), params, cfg, d)
        fn = make_round_fn(cfg, loss_fn, d, unravel)
        pm, energy = params, 0.0
        t0 = time.time()
        for t in range(rounds):
            pm, m = fn(pm, state.power_limits, x, y,
                       jax.random.PRNGKey(seed * 10000 + t))
            energy += float(m["energy"])
        wall = time.time() - t0
        _, acc = evaluate(pm, loss_fn, xt, yt)
        accs.append(acc)
        energies.append(energy)
        subs.append(int(m["subcarriers"]))
        times.append(wall / rounds * 1e6)
    n = len(seeds)
    return {"algorithm": alg, "p": p, "epsilon": eps,
            "accuracy": sum(accs) / n, "energy": sum(energies) / n,
            "subcarriers": subs[0], "us_per_round": sum(times) / n}
