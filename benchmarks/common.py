"""Shared FL-benchmark harness (CPU-scale reproduction of the paper's
experimental protocol, DESIGN.md §2), on the unified Trainer API."""
from __future__ import annotations

import time

import jax
from jax.flatten_util import ravel_pytree

from repro.configs import PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.core.channel import scaled_channel  # shared regime helper
from repro.data import make_federated_classification
from repro.fl import Trainer
from repro.fl.api import replace
from repro.models import cnn


def build_problem(seed=0, n_clients=60, per_client=40, model_cfg=BENCH_MLP):
    key = jax.random.PRNGKey(seed)
    params = cnn.init_cnn(key, model_cfg)
    flat, unravel = ravel_pytree(params)
    data = make_federated_classification(
        key, n_clients=n_clients, per_client=per_client,
        num_classes=model_cfg.num_classes,
        image_shape=(model_cfg.in_channels, model_cfg.image_size,
                     model_cfg.image_size), noise=1.4)
    loss_fn = lambda p, b: cnn.cnn_loss(p, model_cfg, b)
    return params, flat.shape[0], unravel, data, loss_fn


def make_trainer(alg, problem, *, rounds=40, p=0.3, eps=1.5, n_clients=60,
                 r=8, tau=5, lr=0.05, dp_sigma=1.0, **extra):
    """(trainer, initial state) for one benchmark configuration — the one
    construction every fig/beyond benchmark shares."""
    params, d, unravel, _, loss_fn = problem
    cfg = PFELSConfig(num_clients=n_clients, clients_per_round=r,
                      local_steps=tau, local_lr=lr,
                      compression_ratio=p, epsilon=eps, rounds=rounds,
                      momentum=0.9, algorithm=alg,
                      dp_fedavg_sigma=dp_sigma,
                      channel=extra.pop("channel", None)
                      or scaled_channel(d), **extra)
    trainer = Trainer(cfg, loss_fn, params)
    return trainer, trainer.init(jax.random.PRNGKey(1))


def run_fl(alg: str, *, rounds=40, p=0.3, eps=1.5, seeds=(0, 1, 2),
           n_clients=60, r=8, tau=5, lr=0.05, problem=None,
           dp_sigma=1.0):
    """Returns dict with mean final accuracy, energy, subcarriers, and
    us_per_round."""
    prob = problem or build_problem(seed=0, n_clients=n_clients)
    trainer, state0 = make_trainer(alg, prob, rounds=rounds, p=p, eps=eps,
                                   n_clients=n_clients, r=r, tau=tau,
                                   lr=lr, dp_sigma=dp_sigma)
    x, y, xt, yt = prob[3]
    accs, energies, subs, times = [], [], [], []
    for seed in seeds:   # one compiled program, one state per seed key
        state = replace(state0, key=jax.random.PRNGKey(seed * 10000))
        t0 = time.time()
        state, m = trainer.run(state, x, y, rounds=rounds)
        jax.block_until_ready(state.params)
        wall = time.time() - t0
        _, acc = trainer.evaluate(state, xt, yt)
        accs.append(acc)
        energies.append(float(m["energy"].sum()))
        subs.append(int(m["subcarriers"][-1]))
        times.append(wall / rounds * 1e6)
    n = len(seeds)
    return {"algorithm": alg, "p": p, "epsilon": eps,
            "accuracy": sum(accs) / n, "energy": sum(energies) / n,
            "subcarriers": subs[0], "us_per_round": sum(times) / n}
