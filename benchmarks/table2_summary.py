"""Paper Tables 2/3: accuracy / subcarriers / energy for PFELS vs WFL-P vs
WFL-PDP at a fixed privacy budget.

Claims reproduced: PFELS attains >= baseline accuracy with fewer
subcarriers and lower transmit energy.
"""
from __future__ import annotations

from benchmarks.common import build_problem, run_fl


def run(rounds=40, eps=1.5, seeds=(0, 1, 2)):
    problem = build_problem()
    rows = []
    print(f"{'alg':10s} {'acc':>6s} {'subcarriers':>11s} {'energy':>10s}")
    for alg in ("pfels", "wfl_p", "wfl_pdp"):
        r = run_fl(alg, rounds=rounds, eps=eps, seeds=seeds,
                   problem=problem)
        print(f"{alg:10s} {r['accuracy']:6.3f} {r['subcarriers']:11d} "
              f"{r['energy']:10.3e}", flush=True)
        rows.append((f"table2_{alg}", r["us_per_round"],
                     f"acc={r['accuracy']:.3f};sub={r['subcarriers']};"
                     f"energy={r['energy']:.3e}"))
    return rows


if __name__ == "__main__":
    run()
