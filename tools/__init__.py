"""Repo maintenance and static-check tooling.

Everything under ``tools/`` is host-side developer tooling — never imported
by ``src/repro`` — and shares the CLI conventions in :mod:`tools._cli`:
exit 0 on success, 1 on findings/regressions, 2 on unusable input
(schema or baseline mismatch).
"""
