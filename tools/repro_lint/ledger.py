"""Ledger / registry completeness checkers (RL301-RL304).

These close the accounting loop that PAPER.md Thm 3 / Thm 5 depend on:
an algorithm without ``privacy_spend`` silently trains with a zero ledger
(RL301), a compressor without a declared sensitivity factor breaks the
Lemma-2 bound the epsilon charge is computed from (RL302), a registry
entry no test or golden row ever names is an unverified DP surface
(RL303), and a call path that aggregates over the air without charging
``_dp_epsilon_spend`` is exactly the accounting drift arXiv 2304.04164
warns about (RL304).

RL301-303 introspect the *live* registries (importing ``repro``); RL304 is
pure AST over the call graph so it also works on fixture trees.
"""
from __future__ import annotations

import json
import os
import re
from typing import List, Optional

from tools.repro_lint.astutil import ParsedFile
from tools.repro_lint.callgraph import CallGraph, build_graph
from tools.repro_lint.findings import Finding

#: callee names (normalized) that constitute a ledger charge
CHARGE_NAMES = {
    "dp_epsilon_spend", "ledger_spend", "round_epsilon_spent",
    "privacy_spend", "spend",
}

#: callee-name prefix that constitutes an over-the-air aggregation
AIRCOMP_PREFIX = "aircomp_aggregate"


def _registration_line(pf_lines: List[str], name: str) -> int:
    pat = re.compile(r'["\']' + re.escape(name) + r'["\']')
    for i, line in enumerate(pf_lines, start=1):
        if "register" in line and pat.search(line):
            return i
    for i, line in enumerate(pf_lines, start=1):
        if pat.search(line):
            return i
    return 0


def _read_lines(root: str, rel: str) -> List[str]:
    try:
        with open(os.path.join(root, rel), encoding="utf-8") as f:
            return f.read().splitlines()
    except OSError:
        return []


def check_registries(root: str, algorithms=None,
                     compressors=None) -> List[Finding]:
    """RL301 + RL302 against the live registries.

    ``algorithms``/``compressors`` may be injected as {name: record} dicts
    for tests; by default the real ``repro`` registries are imported.
    """
    out: List[Finding] = []
    if algorithms is None:
        from repro.fl import algorithms as _alg
        algorithms = {n: _alg.get_algorithm(n)
                      for n in _alg.list_algorithms()}
        alg_path = "src/repro/fl/algorithms.py"
    else:
        alg_path = "<registry:algorithms>"
    if compressors is None:
        from repro.core.compressors import base as _cb
        compressors = {n: _cb.get_compressor(n)
                       for n in _cb.list_compressors()}
        comp_path = "src/repro/core/compressors/base.py"
    else:
        comp_path = "<registry:compressors>"

    alg_lines = _read_lines(root, alg_path)
    for name in sorted(algorithms):
        if getattr(algorithms[name], "privacy_spend", None) is None:
            out.append(Finding(
                rule="RL301", path=alg_path,
                line=_registration_line(alg_lines, name), col=0,
                message=(f"algorithm '{name}' defines no privacy_spend "
                         "hook; its rounds train with an uncharged "
                         "ledger"),
                symbol=name))
    comp_lines = _read_lines(root, comp_path)
    for name in sorted(compressors):
        if getattr(compressors[name], "sensitivity", None) is None:
            out.append(Finding(
                rule="RL302", path=comp_path,
                line=_registration_line(comp_lines, name), col=0,
                message=(f"compressor '{name}' declares no sensitivity "
                         "factor; the Lemma-2 bound cannot be scaled"),
                symbol=name))
    return out


def check_coverage(root: str, goldens_rel: str = None,
                   tests_rel: str = "tests", names: dict = None
                   ) -> List[Finding]:
    """RL303: every registered algorithm/channel/compressor name must be
    reachable by a test or golden row.

    ``names`` may inject {kind: {name: defining_path}} for tests; the
    default reads the live registries. The haystack is the goldens JSON
    (case names + meta) plus the text of every ``tests_rel/*.py``.
    """
    if goldens_rel is None:
        goldens_rel = os.path.join("tests", "goldens",
                                   "golden_digests.json")
    if names is None:
        from repro.core.channels import base as _ch
        from repro.core.compressors import base as _cb
        from repro.fl import algorithms as _alg
        names = {
            "algorithm": {n: "src/repro/fl/algorithms.py"
                          for n in _alg.list_algorithms()},
            "channel": {n: "src/repro/core/channels/base.py"
                        for n in _ch.list_channel_models()},
            "compressor": {n: "src/repro/core/compressors/base.py"
                           for n in _cb.list_compressors()},
        }

    hay_parts: List[str] = []
    gpath = os.path.join(root, goldens_rel)
    try:
        with open(gpath, encoding="utf-8") as f:
            hay_parts.append(f.read())
    except OSError:
        pass
    tdir = os.path.join(root, tests_rel)
    if os.path.isdir(tdir):
        for dirpath, _dirs, fnames in sorted(os.walk(tdir)):
            for fname in sorted(fnames):
                if fname.endswith(".py"):
                    with open(os.path.join(dirpath, fname),
                              encoding="utf-8") as f:
                        hay_parts.append(f.read())
    hay = "\n".join(hay_parts)

    out: List[Finding] = []
    for kind in sorted(names):
        defs = names[kind]
        for name in sorted(defs):
            if not re.search(r"\b" + re.escape(name) + r"\b", hay):
                lines = _read_lines(root, defs[name])
                out.append(Finding(
                    rule="RL303", path=defs[name],
                    line=_registration_line(lines, name), col=0,
                    message=(f"registered {kind} '{name}' is named by no "
                             f"test and no golden row in {goldens_rel}; "
                             "its DP surface is unverified"),
                    symbol=name))
    return out


def check_goldens_schema(root: str, goldens_rel: str = None) -> Optional[str]:
    """Return an error string if the goldens file is unusable (exit-2
    condition), else None."""
    if goldens_rel is None:
        goldens_rel = os.path.join("tests", "goldens",
                                   "golden_digests.json")
    gpath = os.path.join(root, goldens_rel)
    try:
        with open(gpath, encoding="utf-8") as f:
            data = json.load(f)
    except OSError as e:
        return f"goldens file unreadable: {e}"
    except json.JSONDecodeError as e:
        return f"goldens file is not valid JSON: {e}"
    if not isinstance(data, dict) or "cases" not in data or \
            not isinstance(data["cases"], dict):
        return f"goldens file {goldens_rel} has no 'cases' table"
    return None


def check_aircomp_charge(files: List[ParsedFile],
                         graph: CallGraph = None) -> List[Finding]:
    """RL304: no call-graph root may reach ``aircomp_aggregate*`` without
    also reaching a ledger charge.

    Roots are nodes nothing else calls. The aggregation module itself is
    exempt (it *implements* the primitive; the charge lives with the
    caller, see DESIGN.md §8).
    """
    if graph is None:
        graph = build_graph(files)

    callees_of = {k: {c for c, _ in n.calls} for k, n in graph.nodes.items()}
    called: set = set()
    for key, fn in graph.nodes.items():
        for c in callees_of[key]:
            for tgt in graph.targets(c, fn.path):
                if tgt != key:
                    called.add(tgt)

    def reaches(start: str, pred) -> bool:
        seen = {start}
        stack = [start]
        while stack:
            key = stack.pop()
            fn = graph.nodes[key]
            for c in callees_of[key]:
                if pred(c):
                    return True
                for tgt in graph.targets(c, fn.path):
                    if tgt not in seen:
                        seen.add(tgt)
                        stack.append(tgt)
        return False

    def is_aircomp(name: str) -> bool:
        return name.startswith(AIRCOMP_PREFIX)

    def is_charge(name: str) -> bool:
        return name in CHARGE_NAMES

    out: List[Finding] = []
    for key in sorted(graph.nodes):
        fn = graph.nodes[key]
        if key in called:
            continue
        if fn.path.endswith("core/aggregation.py"):
            continue
        if not reaches(key, is_aircomp):
            continue
        if reaches(key, is_charge):
            continue
        out.append(Finding(
            rule="RL304", path=fn.path, line=fn.node.lineno, col=0,
            message=(f"call path rooted at {fn.qualname} reaches "
                     f"{AIRCOMP_PREFIX}* but never charges the ledger "
                     "(_dp_epsilon_spend / ledger_spend)"),
            source=fn.pf.src(fn.node.lineno), symbol=fn.qualname))
    return out
