"""Baseline (allowlist) handling for ``tools.repro_lint``.

``baseline.toml`` holds the *reviewed, intentional* exceptions — each
entry must say why. An entry matches a finding by rule + path, optionally
narrowed by a ``match`` substring of the flagged source line and/or a
``symbol`` (enclosing function or registry name). Schema errors and stale
entries (matching nothing — the violation was fixed or the line moved)
are exit-2 conditions: a baseline that silently rots is worse than none.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from tools._cli import ToolError
from tools.repro_lint.findings import RULES, Finding

try:
    import tomllib as _toml          # py311+
except ImportError:                  # pragma: no cover - py310 path
    import tomli as _toml

_ALLOWED_KEYS = {"rule", "path", "match", "symbol", "reason"}


class BaselineError(ToolError):
    """Malformed or stale baseline — exit 2."""


@dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    reason: str
    match: str = ""
    symbol: str = ""

    def matches(self, f: Finding) -> bool:
        if f.rule != self.rule or f.path != self.path:
            return False
        if self.match and self.match not in (f.source or ""):
            return False
        if self.symbol and self.symbol != f.symbol:
            return False
        return True

    def render(self) -> str:
        extra = "".join(
            f" {k}={v!r}" for k, v in
            (("match", self.match), ("symbol", self.symbol)) if v)
        return f"[{self.rule} path={self.path!r}{extra}]"


def load_baseline(path: str) -> List[BaselineEntry]:
    try:
        with open(path, "rb") as f:
            data = _toml.load(f)
    except OSError as e:
        raise BaselineError(f"baseline unreadable: {e}")
    except _toml.TOMLDecodeError as e:
        raise BaselineError(f"baseline is not valid TOML: {e}")

    raw = data.pop("entry", [])
    if data:
        raise BaselineError(
            f"unknown top-level baseline keys {sorted(data)}; entries go "
            "in [[entry]] tables")
    if not isinstance(raw, list):
        raise BaselineError("[[entry]] must be an array of tables")

    entries: List[BaselineEntry] = []
    for i, item in enumerate(raw):
        where = f"baseline entry #{i + 1}"
        if not isinstance(item, dict):
            raise BaselineError(f"{where}: not a table")
        unknown = set(item) - _ALLOWED_KEYS
        if unknown:
            raise BaselineError(f"{where}: unknown keys {sorted(unknown)}")
        for req in ("rule", "path", "reason"):
            if not isinstance(item.get(req), str) or not item[req].strip():
                raise BaselineError(
                    f"{where}: missing/empty required key '{req}'")
        if item["rule"] not in RULES:
            raise BaselineError(
                f"{where}: unknown rule id {item['rule']!r}")
        entries.append(BaselineEntry(
            rule=item["rule"], path=item["path"], reason=item["reason"],
            match=item.get("match", ""), symbol=item.get("symbol", "")))
    return entries


def apply_baseline(findings: List[Finding],
                   entries: List[BaselineEntry]
                   ) -> Tuple[List[Finding], List[Finding],
                              List[BaselineEntry]]:
    """Split findings into (kept, suppressed); also return stale entries
    that matched nothing (an exit-2 condition for the caller)."""
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    used = [False] * len(entries)
    for f in findings:
        hit = False
        for i, e in enumerate(entries):
            if e.matches(f):
                used[i] = True
                hit = True
        (suppressed if hit else kept).append(
            f.as_baselined() if hit else f)
    stale = [e for i, e in enumerate(entries) if not used[i]]
    return kept, suppressed, stale
