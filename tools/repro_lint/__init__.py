"""``replint`` — repo-specific static analysis proving the PRNG-lane,
trace-safety and privacy-ledger invariants (DESIGN.md §14).

Run it as ``PYTHONPATH=src python -m tools.repro_lint src/``. Three
checker families, each a module here:

- PRNG hygiene (RL101-RL104): :mod:`tools.repro_lint.prng`
- trace safety (RL201-RL206): :mod:`tools.repro_lint.trace` (AST) and
  :mod:`tools.repro_lint.jaxpr_scan` (lowered jaxprs)
- ledger/registry completeness (RL301-RL304):
  :mod:`tools.repro_lint.ledger`

Intentional exceptions live in ``baseline.toml`` next to this package;
every entry carries a reason and goes stale (exit 2) the moment the code
it blesses changes.
"""
from tools.repro_lint.findings import RULES, Finding, sort_findings

__all__ = ["Finding", "RULES", "sort_findings"]
