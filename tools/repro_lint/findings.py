"""Finding record and the rule table for ``tools.repro_lint``.

Rule IDs are stable identifiers: baselines (``baseline.toml``), tests and
DESIGN.md §14 all key on them. Never renumber; retire by deleting.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

#: rule id -> (slug, one-line description). Kept in sync with DESIGN.md §14.
RULES = {
    # -- PRNG hygiene ------------------------------------------------------
    "RL101": ("prng-key-reuse",
              "key consumed by >=2 random draws without an interleaving "
              "split/fold_in"),
    "RL102": ("raw-prngkey",
              "raw PRNGKey()/key() construction outside sanctioned sites "
              "(launch/, tests/, examples/)"),
    "RL103": ("lane-literal",
              "integer lane subscript on a split_round_key result; use "
              "ROUND_KEY_LANES[\"...\"]"),
    "RL104": ("dup-stream-tag",
              "duplicate fold_in stream tag across modules, or a magic "
              "literal shadowing a *TAG constant"),
    # -- trace safety ------------------------------------------------------
    "RL201": ("traced-branch",
              "Python if/while/ternary on a traced value inside "
              "cohort-core-reachable code"),
    "RL202": ("host-coercion",
              ".item()/float()/int()/bool() on a traced value inside "
              "cohort-core-reachable code"),
    "RL203": ("dynamic-shape",
              "jnp.nonzero/flatnonzero/argwhere/unique without size=, or "
              "1-arg jnp.where"),
    "RL204": ("bool-mask-index",
              "boolean-mask indexing (data-dependent shape under jit)"),
    "RL205": ("host-callback",
              "device_get/callback/numpy host op inside "
              "cohort-core-reachable code"),
    "RL206": ("jaxpr-forbidden",
              "forbidden primitive (callback/host transfer) or non-static "
              "shape found in a lowered round jaxpr"),
    # -- ledger / registry completeness ------------------------------------
    "RL301": ("alg-no-spend",
              "registered algorithm does not define privacy_spend"),
    "RL302": ("comp-no-sensitivity",
              "registered compressor does not declare a sensitivity factor"),
    "RL303": ("combo-unreachable",
              "registered algorithm/channel/compressor name not reachable "
              "by any test or golden row"),
    "RL304": ("uncharged-aircomp",
              "call path reaches aircomp_aggregate* without a ledger "
              "charge in the same round body"),
}


@dataclass(frozen=True)
class Finding:
    """One violation. ``source`` is the stripped flagged line (the target of
    a baseline entry's ``match``); ``symbol`` is the enclosing function
    qualname or registry entry name when one exists."""

    rule: str
    path: str              # repo-relative posix path (or "<jaxpr:...>")
    line: int
    col: int
    message: str
    source: str = ""
    symbol: str = ""
    baselined: bool = field(default=False, compare=False)

    def render(self) -> str:
        slug = RULES[self.rule][0]
        loc = f"{self.path}:{self.line}:{self.col}"
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{loc}: {self.rule} ({slug}){sym} {self.message}"

    def as_baselined(self) -> "Finding":
        return replace(self, baselined=True)


def sort_findings(findings):
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))
