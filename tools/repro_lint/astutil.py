"""Shared AST plumbing: parsed-file records, import-alias resolution, and
dotted-name reconstruction.

All checkers resolve call targets through :func:`dotted_name` so that
``import jax.numpy as jnp; jnp.nonzero(x)`` and
``from jax import numpy; numpy.nonzero(x)`` both canonicalize to
``jax.numpy.nonzero``.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class ParsedFile:
    path: str                      # repo-relative posix path
    tree: ast.Module
    lines: List[str]               # source lines (for Finding.source)
    imports: Dict[str, str] = field(default_factory=dict)

    def src(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


def parse_file(abspath: str, relpath: str) -> ParsedFile:
    with open(abspath, "r", encoding="utf-8") as f:
        text = f.read()
    tree = ast.parse(text, filename=relpath)
    pf = ParsedFile(path=relpath.replace(os.sep, "/"), tree=tree,
                    lines=text.splitlines())
    pf.imports = collect_imports(tree)
    return pf


def collect_imports(tree: ast.Module) -> Dict[str, str]:
    """Map local alias -> canonical dotted module/name.

    ``import jax.numpy as jnp``      -> {"jnp": "jax.numpy"}
    ``import numpy``                 -> {"numpy": "numpy"}
    ``from jax import random as jr`` -> {"jr": "jax.random"}
    ``from jax.random import split`` -> {"split": "jax.random.split"}
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.level or not node.module:
                continue   # relative imports stay unresolved
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def dotted_name(node: ast.AST, imports: Dict[str, str]) -> Optional[str]:
    """Canonical dotted name of a Name/Attribute chain, alias-resolved.

    Returns None for anything that is not a plain attribute chain rooted at
    a Name (e.g. calls on call results)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = imports.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def call_name(call: ast.Call, imports: Dict[str, str]) -> Optional[str]:
    return dotted_name(call.func, imports)


def terminal(dotted: Optional[str]) -> str:
    return dotted.rsplit(".", 1)[-1] if dotted else ""


def iter_functions(tree: ast.Module):
    """Yield (qualname, node) for every def/async def, including methods
    and nested functions. Qualnames use dots: ``Trainer._spend``,
    ``_build_cohort_core.cohort_core``."""

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from walk(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def walk_own(fn: ast.AST):
    """Walk a node without descending into nested defs/lambdas — those are
    scanned as their own units, so this prevents double-reporting."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def norm(name: str) -> str:
    """Normalize an identifier for call-graph matching: strip leading
    underscores so ``self._cohort_core`` matches ``cohort_core``."""
    return name.lstrip("_")
