"""CLI driver for ``tools.repro_lint``.

    PYTHONPATH=src python -m tools.repro_lint src/

Exit codes follow tools/_cli.py (the check_bench.py convention): 0 clean,
1 findings, 2 unusable input (syntax error in a scanned file, malformed
goldens, malformed or stale baseline).
"""
from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools._cli import (EXIT_FINDINGS, EXIT_OK, EXIT_SCHEMA, ROOT,
                        ToolError, add_src_to_path, run_main)
from tools.repro_lint import jaxpr_scan, ledger, prng, trace
from tools.repro_lint.astutil import parse_file
from tools.repro_lint.baseline import apply_baseline, load_baseline
from tools.repro_lint.findings import RULES, sort_findings

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baseline.toml")

#: path fragments where raw PRNGKey construction is sanctioned (RL102)
SANCTIONED_PRNG = ("/launch/", "/tests/", "/examples/")

TRACE_ROOTS = ("_build_cohort_core",)
LANE_SPLIT_FNS = ("split_round_key",)


def collect_py_files(paths):
    out = []
    for p in paths:
        ap = os.path.abspath(p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.append(ap)
        elif os.path.isdir(ap):
            for dirpath, dirs, names in sorted(os.walk(ap)):
                dirs[:] = sorted(d for d in dirs if d != "__pycache__")
                out.extend(os.path.join(dirpath, n)
                           for n in sorted(names) if n.endswith(".py"))
        else:
            raise ToolError(f"no such file or directory: {p}")
    return out


def run_ast_checks(files, sanctioned_prng=SANCTIONED_PRNG,
                   trace_roots=TRACE_ROOTS,
                   lane_split_fns=LANE_SPLIT_FNS):
    """All pure-AST rules over already-parsed files (library entry point —
    tests/test_replint.py drives fixture trees through this)."""
    findings = []
    for pf in files:
        findings += prng.check_key_reuse(pf)
        findings += prng.check_raw_prngkey(pf, sanctioned_prng)
        findings += prng.check_lane_literals(pf, lane_split_fns)
        findings += trace.check_file_trace(pf)
    findings += prng.check_stream_tags(files)
    findings += trace.check_reachable(files, trace_roots)
    findings += ledger.check_aircomp_charge(files)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="PFELS invariant lint (DESIGN.md §14)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to scan (default: src/)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="allowlist TOML (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings too")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the RL206 lowered-round scan (needs jax)")
    ap.add_argument("--no-registry", action="store_true",
                    help="skip RL301-RL303 (needs importing repro)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            slug, desc = RULES[rid]
            print(f"{rid}  {slug:<20} {desc}")
        return EXIT_OK

    paths = args.paths or [os.path.join(ROOT, "src")]
    files = []
    for ap_ in collect_py_files(paths):
        rel = os.path.relpath(ap_, ROOT)
        if rel.startswith(".."):
            rel = ap_
        try:
            files.append(parse_file(ap_, rel))
        except SyntaxError as e:
            raise ToolError(f"cannot parse {rel}: {e}")

    findings = run_ast_checks(files)

    if not args.no_registry:
        add_src_to_path()
        err = ledger.check_goldens_schema(ROOT)
        if err:
            raise ToolError(err)
        findings += ledger.check_registries(ROOT)
        findings += ledger.check_coverage(ROOT)

    if not args.no_jaxpr:
        add_src_to_path()
        findings += jaxpr_scan.lint_lowered_rounds()

    suppressed = []
    if not args.no_baseline and os.path.exists(args.baseline):
        entries = load_baseline(args.baseline)
        findings, suppressed, stale = apply_baseline(findings, entries)
        if stale:
            lines = "\n".join("  " + e.render() for e in stale)
            raise ToolError(
                "stale baseline entries (match no current finding — fix "
                f"the baseline):\n{lines}")

    findings = sort_findings(findings)
    for f in findings:
        print(f.render())

    n_files = len(files)
    if findings:
        print(f"\nreplint: {len(findings)} finding(s) in {n_files} "
              f"file(s) ({len(suppressed)} baselined)", file=sys.stderr)
        return EXIT_FINDINGS
    print(f"replint: clean ({n_files} files scanned, "
          f"{len(suppressed)} baselined)", file=sys.stderr)
    return EXIT_OK


if __name__ == "__main__":
    run_main(main)


# re-exported for tests
__all__ = ["main", "run_ast_checks", "collect_py_files",
           "EXIT_OK", "EXIT_FINDINGS", "EXIT_SCHEMA"]
