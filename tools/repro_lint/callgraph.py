"""Name-based over-approximating call graph.

Resolution is intentionally coarse (no types, no dataflow): a call edge is
drawn from a function to every known function whose *normalized* name
matches the callee (leading underscores stripped, so ``self._cohort_core``
reaches ``cohort_core``). Two extras make this useful on this codebase:

- nested defs are indexed as their own nodes (closures inside
  ``_build_cohort_core`` are graph nodes reachable from it);
- dataclass-style hook wiring is aliased: ``Algorithm(design_beta=f)``
  registers ``design_beta -> f`` so later ``alg.design_beta(...)`` calls
  resolve to every hook implementation wired under that keyword.

Over-approximation is the right failure mode for a lint: it can only add
reachable code, never hide it.
"""
from __future__ import annotations

import ast
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Set, Tuple

from tools.repro_lint.astutil import (ParsedFile, call_name, iter_functions,
                                      norm, terminal)


@dataclass
class FuncNode:
    key: str               # "<path>:<qualname>"
    path: str
    qualname: str
    node: ast.AST
    pf: ParsedFile
    calls: List[Tuple[str, int]] = field(default_factory=list)


@dataclass
class CallGraph:
    nodes: Dict[str, FuncNode]
    by_name: Dict[str, List[str]]            # normalized name -> node keys
    aliases: Dict[str, Set[str]]             # hook keyword -> node keys

    def targets(self, called: str, from_path: str) -> List[str]:
        """Node keys a normalized callee name may resolve to. Same-module
        definitions win when they exist (shadowing)."""
        cands = self.by_name.get(called, [])
        cands = cands + sorted(self.aliases.get(called, ()))
        local = [k for k in cands if self.nodes[k].path == from_path]
        return local if local else cands

    def reachable(self, root_names: Set[str]) -> Set[str]:
        """BFS over the edge relation from every node whose terminal
        qualname component matches a root name."""
        roots = [k for k, n in self.nodes.items()
                 if norm(n.qualname.rsplit(".", 1)[-1]) in
                 {norm(r) for r in root_names}]
        seen: Set[str] = set(roots)
        q = deque(roots)
        while q:
            key = q.popleft()
            fn = self.nodes[key]
            for called, _lineno in fn.calls:
                for tgt in self.targets(called, fn.path):
                    if tgt not in seen:
                        seen.add(tgt)
                        q.append(tgt)
        return seen


#: ubiquitous ndarray/container method names that must not resolve to
#: same-named repo functions (``x.flatten()`` is not ``checkpoint._flatten``)
_METHOD_STOPLIST = {
    "flatten", "ravel", "reshape", "astype", "copy", "tolist", "sum",
    "mean", "get", "items", "keys", "values", "append", "update",
}


def _callee_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return norm(f.id)
    if isinstance(f, ast.Attribute):
        name = norm(f.attr)
        return "" if name in _METHOD_STOPLIST else name
    return ""


def build_graph(files: List[ParsedFile]) -> CallGraph:
    nodes: Dict[str, FuncNode] = {}
    by_name: Dict[str, List[str]] = {}
    aliases: Dict[str, Set[str]] = {}
    # name of module-level def per file, for hook-alias resolution
    module_defs: Dict[str, Dict[str, str]] = {}

    for pf in files:
        module_defs[pf.path] = {}
        for qual, fn in iter_functions(pf.tree):
            key = f"{pf.path}:{qual}"
            fnode = FuncNode(key=key, path=pf.path, qualname=qual, node=fn,
                             pf=pf)
            nodes[key] = fnode
            by_name.setdefault(norm(fn.name), []).append(key)
            if "." not in qual:
                module_defs[pf.path][fn.name] = key

    for pf in files:
        for qual, fn in iter_functions(pf.tree):
            key = f"{pf.path}:{qual}"
            fnode = nodes[key]
            # a builder always "reaches" the closures it defines
            for child in ast.iter_child_nodes(fn):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    fnode.calls.append((norm(child.name), child.lineno))
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    name = _callee_name(node)
                    if name:
                        fnode.calls.append((name, node.lineno))
                # bare function references (passed as values) also count as
                # potential edges: rounds-builders return/forward closures.
                elif isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Load) and \
                        norm(node.id) in by_name:
                    fnode.calls.append((norm(node.id), node.lineno))

        # hook aliasing: SomeRegistryRecord(hook_name=local_def, ...)
        for node in ast.walk(pf.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg is None:
                    continue
                if isinstance(kw.value, ast.Name):
                    tgt = module_defs[pf.path].get(kw.value.id)
                    if tgt is not None:
                        aliases.setdefault(norm(kw.arg), set()).add(tgt)

    return CallGraph(nodes=nodes, by_name=by_name, aliases=aliases)


__all__ = ["CallGraph", "FuncNode", "build_graph", "call_name", "terminal"]
