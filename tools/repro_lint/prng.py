"""PRNG hygiene checkers (RL101-RL104).

The PFELS DP claim (PAPER.md Thm 3) only holds if every random draw comes
from a distinct PRNG stream: the 7-lane ``ROUND_KEY_LANES`` contract in
``src/repro/fl/rounds.py`` plus per-subsystem ``fold_in`` stream tags.
These rules catch the silent failure modes: a key consumed twice (RL101),
an ad-hoc root key smuggled into library code (RL102), a lane addressed by
magic integer so a contract change silently re-wires streams (RL103), and
two subsystems folding the same tag into the same lane (RL104).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Tuple

from tools.repro_lint.astutil import (ParsedFile, call_name, iter_functions,
                                      terminal)
from tools.repro_lint.findings import Finding

#: jax.random.* callees that derive/construct keys rather than draw from
#: them — consuming a key through these is not a "draw" for RL101.
_KEY_DERIVERS = {
    "split", "fold_in", "clone", "PRNGKey", "key", "key_data",
    "wrap_key_data", "key_impl",
}

_KEYISH_PARAM = re.compile(r"(key|rng)s?$|^ks$")

_TAG_CONST = re.compile(r"[A-Za-z0-9_]*TAG$")

#: Sentinel for "not inside any loop" in the RL101 visitor.
_NOT_IN_LOOP = frozenset()


def _is_random_call(call: ast.Call, imports) -> Optional[str]:
    """Return the jax.random.* terminal name if this call is a random op."""
    dotted = call_name(call, imports)
    if dotted and dotted.startswith("jax.random."):
        return terminal(dotted)
    return None


def _key_expr_id(node: ast.AST) -> Optional[Tuple]:
    """Hashable identity for a key expression: a Name or a constant-ish
    subscript of a Name (``ks[3]``, ``ks[LANES["gains"]]``)."""
    if isinstance(node, ast.Name):
        return ("n", node.id)
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
        try:
            sl = ast.unparse(node.slice)
        except Exception:
            return None
        return ("s", node.value.id, sl)
    return None


def _base_name(key_id: Tuple) -> str:
    return key_id[1]


class _KeyReuseVisitor:
    """Per-function RL101 scan.

    Tracks, per key identity, the list of draw events seen since the last
    reassignment. Two draws conflict unless they live in mutually exclusive
    arms of the same ``if``. A draw inside a loop whose key is not
    re-derived in that loop body conflicts with itself.

    ``path`` is the branch context: a tuple of (if-node-id, arm) entries.
    """

    def __init__(self, pf: ParsedFile, qualname: str):
        self.pf = pf
        self.qualname = qualname
        self.findings: List[Finding] = []
        # key id -> list of (branch_path, lineno, op)
        self.draws: Dict[Tuple, List[Tuple[tuple, int, str]]] = {}
        self.key_vars: set = set()

    # -- helpers -----------------------------------------------------------

    def _reset(self, name: str):
        for kid in list(self.draws):
            if _base_name(kid) == name:
                del self.draws[kid]

    @staticmethod
    def _exclusive(a: tuple, b: tuple) -> bool:
        for ea, eb in zip(a, b):
            if ea[0] == eb[0] and ea[1] != eb[1]:
                return True
            if ea != eb:
                return False
        return False

    def _record_draw(self, kid: Tuple, path: tuple, lineno: int, op: str,
                     twice: bool):
        prior = self.draws.setdefault(kid, [])
        events = [(path, lineno, op)] * (2 if twice else 1)
        for ev in events:
            for (ppath, plineno, pop) in prior:
                if not self._exclusive(ppath, ev[0]):
                    base = (f"key `{self._render(kid)}` drawn by "
                            f"jax.random.{op} at line {lineno}")
                    if plineno == lineno and pop == op:
                        msg = (base + " inside a loop without re-splitting "
                               "per iteration")
                    else:
                        msg = (base + f" was already consumed by "
                               f"jax.random.{pop} at line {plineno} with no "
                               "interleaving split/fold_in")
                    self.findings.append(Finding(
                        rule="RL101", path=self.pf.path, line=lineno,
                        col=0, message=msg, source=self.pf.src(lineno),
                        symbol=self.qualname))
                    prior.clear()
                    break
            prior.append(ev)

    @staticmethod
    def _render(kid: Tuple) -> str:
        return kid[1] if kid[0] == "n" else f"{kid[1]}[{kid[2]}]"

    def _is_tracked(self, kid: Tuple) -> bool:
        return _base_name(kid) in self.key_vars

    # -- driver ------------------------------------------------------------

    def run(self, fn: ast.AST):
        args = getattr(fn, "args", None)
        if args is not None:
            for a in (args.posonlyargs + args.args + args.kwonlyargs
                      + ([args.vararg] if args.vararg else [])
                      + ([args.kwarg] if args.kwarg else [])):
                if a and _KEYISH_PARAM.search(a.arg):
                    self.key_vars.add(a.arg)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        self._visit_stmts(body, (), loop_assigned=_NOT_IN_LOOP)
        return self.findings

    def _assigned_names(self, stmts) -> set:
        out = set()
        for node in stmts:
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                                    ast.NamedExpr)):
                    targets = (sub.targets if isinstance(sub, ast.Assign)
                               else [sub.target])
                    for t in targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                out.add(n.id)
                elif isinstance(n := sub, ast.For):
                    for nn in ast.walk(n.target):
                        if isinstance(nn, ast.Name):
                            out.add(nn.id)
        return out

    def _visit_stmts(self, stmts, path, loop_assigned):
        for stmt in stmts:
            self._visit_stmt(stmt, path, loop_assigned)

    def _visit_stmt(self, stmt, path, loop_assigned):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return   # nested scopes are scanned as their own functions
        if isinstance(stmt, ast.If):
            self._scan_expr(stmt.test, path, loop_assigned)
            nid = id(stmt)
            self._visit_stmts(stmt.body, path + ((nid, 0),), loop_assigned)
            self._visit_stmts(stmt.orelse, path + ((nid, 1),), loop_assigned)
            return
        if isinstance(stmt, (ast.For, ast.While)):
            outer = set() if loop_assigned is _NOT_IN_LOOP else loop_assigned
            if isinstance(stmt, ast.For):
                self._scan_expr(stmt.iter, path, loop_assigned)
                for n in ast.walk(stmt.target):
                    if isinstance(n, ast.Name):
                        self._reset(n.id)
                inner = outer | self._assigned_names(stmt.body) | {
                    n.id for n in ast.walk(stmt.target)
                    if isinstance(n, ast.Name)}
            else:
                self._scan_expr(stmt.test, path, loop_assigned)
                inner = outer | self._assigned_names(stmt.body)
            self._visit_stmts(stmt.body, path, loop_assigned=inner)
            self._visit_stmts(stmt.orelse, path, loop_assigned)
            return
        if isinstance(stmt, (ast.Try,)):
            self._visit_stmts(stmt.body, path, loop_assigned)
            for h in stmt.handlers:
                self._visit_stmts(h.body, path, loop_assigned)
            self._visit_stmts(stmt.orelse, path, loop_assigned)
            self._visit_stmts(stmt.finalbody, path, loop_assigned)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._scan_expr(item.context_expr, path, loop_assigned)
            self._visit_stmts(stmt.body, path, loop_assigned)
            return
        # leaf statement: scan expressions, then apply reassignments
        self._scan_expr(stmt, path, loop_assigned)
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                             ast.NamedExpr)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            derives = self._value_derives_key(stmt)
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        self._reset(n.id)
                        if derives:
                            self.key_vars.add(n.id)

    def _value_derives_key(self, stmt) -> bool:
        value = getattr(stmt, "value", None)
        if value is None:
            return False
        for node in ast.walk(value):
            if isinstance(node, ast.Call):
                op = _is_random_call(node, self.pf.imports)
                if op in _KEY_DERIVERS:
                    return True
                dotted = call_name(node, self.pf.imports)
                if terminal(dotted) == "split_round_key":
                    return True
        return False

    def _scan_expr(self, stmt, path, loop_assigned):
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            op = _is_random_call(node, self.pf.imports)
            if op is None or op in _KEY_DERIVERS or not node.args:
                continue
            kid = _key_expr_id(node.args[0])
            if kid is None or not self._is_tracked(kid):
                continue
            # Inside a loop, a draw on a key that the loop body never
            # re-derives repeats the same stream every iteration: record it
            # twice so it conflicts with itself.
            twice = (loop_assigned is not _NOT_IN_LOOP
                     and _base_name(kid) not in loop_assigned)
            self._record_draw(kid, path, node.lineno, op, twice)


def check_key_reuse(pf: ParsedFile) -> List[Finding]:
    """RL101 over every function in the file (module body excluded: keys at
    module scope are flagged by RL102 instead)."""
    out: List[Finding] = []
    for qual, fn in iter_functions(pf.tree):
        v = _KeyReuseVisitor(pf, qual)
        out.extend(v.run(fn))
    return out


def check_raw_prngkey(pf: ParsedFile, sanctioned) -> List[Finding]:
    """RL102: raw PRNGKey()/key() construction outside sanctioned dirs."""
    p = "/" + pf.path
    if any(frag in p for frag in sanctioned):
        return []
    func_of = {}
    for qual, fn in iter_functions(pf.tree):
        for node in ast.walk(fn):
            func_of[id(node)] = qual
    out = []
    for node in ast.walk(pf.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = call_name(node, pf.imports)
        if dotted in ("jax.random.PRNGKey", "jax.random.key"):
            out.append(Finding(
                rule="RL102", path=pf.path, line=node.lineno,
                col=node.col_offset,
                message=(f"raw {terminal(dotted)}() outside sanctioned "
                         "sites; thread a key from the caller instead"),
                source=pf.src(node.lineno),
                symbol=func_of.get(id(node), "<module>")))
    return out


#: variable names that by repo convention hold the split_round_key result
#: even when it arrives as a parameter (the lane tuple is threaded through
#: closures and lambdas as ``ks``)
_LANE_VAR_NAMES = {"ks"}


def check_lane_literals(pf: ParsedFile, lane_split_fns) -> List[Finding]:
    """RL103: integer subscripts on a split_round_key result.

    Lane vars are (a) any variable assigned from ``split_round_key(...)``
    anywhere in the file, and (b) — only in files that themselves name
    ``split_round_key``/``ROUND_KEY_LANES``, i.e. the round plumbing —
    the conventional ``ks`` name, which the builders thread through
    closures and lambdas as a parameter. Model-init code that happens to
    call its own split result ``ks`` is out of scope."""
    in_lane_code = any(
        isinstance(n, ast.Name)
        and n.id in ("split_round_key", "ROUND_KEY_LANES")
        for n in ast.walk(pf.tree)) or any(
        isinstance(n, ast.Attribute)
        and n.attr in ("split_round_key", "ROUND_KEY_LANES")
        for n in ast.walk(pf.tree))
    lane_vars = set(_LANE_VAR_NAMES) if in_lane_code else set()
    for node in ast.walk(pf.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if terminal(call_name(node.value, pf.imports)) in lane_split_fns:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        lane_vars.add(t.id)
    func_of = {}
    for qual, fn in iter_functions(pf.tree):
        for node in ast.walk(fn):
            func_of[id(node)] = qual
    out: List[Finding] = []
    for node in ast.walk(pf.tree):
        if (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and node.value.id in lane_vars
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, int)):
            out.append(Finding(
                rule="RL103", path=pf.path, line=node.lineno,
                col=node.col_offset,
                message=(f"lane literal {node.value.id}"
                         f"[{node.slice.value}]; address lanes as "
                         f'{node.value.id}[ROUND_KEY_LANES["..."]]'),
                source=pf.src(node.lineno),
                symbol=func_of.get(id(node), "<module>")))
    return out


def check_stream_tags(files: List[ParsedFile]) -> List[Finding]:
    """RL104: repo-wide stream-tag registry.

    Collects every module-level ``*TAG = <int>`` constant and every integer
    literal passed as the second argument of ``fold_in``. Fails on (a) two
    constants with the same value, (b) a literal that shadows a constant's
    value, (c) the same literal folded in from two different modules.
    """
    consts: List[Tuple[int, str, str, int]] = []   # (value, name, path, line)
    literals: List[Tuple[int, str, int]] = []       # (value, path, line)
    src = {}
    for pf in files:
        src[pf.path] = pf
        for node in pf.tree.body:
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
            for t in targets:
                if (isinstance(t, ast.Name) and _TAG_CONST.match(t.id)
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, int)):
                    consts.append((node.value.value, t.id, pf.path,
                                   node.lineno))
        for node in ast.walk(pf.tree):
            if (isinstance(node, ast.Call)
                    and terminal(call_name(node, pf.imports)) == "fold_in"
                    and len(node.args) >= 2
                    and isinstance(node.args[1], ast.Constant)
                    and isinstance(node.args[1].value, int)):
                literals.append((node.args[1].value, pf.path, node.lineno))

    out: List[Finding] = []
    by_value: Dict[int, Tuple[int, str, str, int]] = {}
    for value, name, path, line in sorted(consts, key=lambda c: (c[2], c[3])):
        if value in by_value:
            _, pname, ppath, _ = by_value[value]
            out.append(Finding(
                rule="RL104", path=path, line=line, col=0,
                message=(f"stream tag {name} = {value:#x} duplicates "
                         f"{pname} in {ppath}; streams would collide"),
                source=src[path].src(line), symbol=name))
        else:
            by_value[value] = (value, name, path, line)

    lit_seen: Dict[int, Tuple[str, int]] = {}
    for value, path, line in sorted(literals, key=lambda c: (c[1], c[2])):
        if value in by_value:
            _, pname, ppath, _ = by_value[value]
            out.append(Finding(
                rule="RL104", path=path, line=line, col=0,
                message=(f"magic fold_in tag {value:#x} duplicates constant "
                         f"{pname} ({ppath}); reference the constant"),
                source=src[path].src(line)))
        elif value in lit_seen and lit_seen[value][0] != path:
            ppath, pline = lit_seen[value]
            out.append(Finding(
                rule="RL104", path=path, line=line, col=0,
                message=(f"fold_in tag {value:#x} already used in "
                         f"{ppath}:{pline}; register a distinct *TAG "
                         "constant per stream"),
                source=src[path].src(line)))
        else:
            lit_seen.setdefault(value, (path, line))
    return out
