"""Trace-safety checkers (RL201-RL205).

``_build_cohort_core`` returns the closure that ``lax.scan``/``jit``
compiles; anything reachable from it runs under tracing, where Python
control flow on traced values, host coercions, data-dependent shapes and
callbacks either crash (ConcretizationTypeError) or silently punch holes
in the compiled graph. RL201/202/205 are scoped to the reachable set via
the over-approximating call graph; RL203/204 (dynamic shapes) are unsafe
under jit anywhere in ``src/`` and are checked file-wide.
"""
from __future__ import annotations

import ast
from typing import List, Set

from tools.repro_lint.astutil import ParsedFile, call_name, walk_own
from tools.repro_lint.callgraph import CallGraph, build_graph
from tools.repro_lint.findings import Finding

#: dotted prefixes whose call results are traced values
_TRACED_PREFIXES = ("jax.numpy.", "jax.lax.", "jax.random.", "jax.nn.")

_SIZE_REQUIRED = {
    "jax.numpy.nonzero", "jax.numpy.flatnonzero", "jax.numpy.argwhere",
    "jax.numpy.unique",
}

_HOST_CALLS = {
    "jax.device_get", "jax.device_put", "jax.pure_callback",
    "jax.experimental.io_callback", "jax.debug.callback",
    "jax.experimental.host_callback.call",
}


def _has_traced_call(node: ast.AST, imports) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            dotted = call_name(sub, imports)
            if dotted and (dotted.startswith(_TRACED_PREFIXES)
                           or dotted in ("jax.numpy", "jax.lax")):
                return True
    return False


def check_file_trace(pf: ParsedFile) -> List[Finding]:
    """File-wide rules: RL203 (dynamic-shape ops) and RL204 (boolean-mask
    indexing)."""
    out: List[Finding] = []
    for node in ast.walk(pf.tree):
        if isinstance(node, ast.Call):
            dotted = call_name(node, pf.imports)
            if dotted in _SIZE_REQUIRED:
                if not any(kw.arg == "size" for kw in node.keywords):
                    out.append(Finding(
                        rule="RL203", path=pf.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"{dotted} without size= has a "
                                 "data-dependent output shape; pass "
                                 "size=/fill_value="),
                        source=pf.src(node.lineno)))
            elif dotted == "jax.numpy.where" and len(node.args) == 1 \
                    and not node.keywords:
                out.append(Finding(
                    rule="RL203", path=pf.path, line=node.lineno,
                    col=node.col_offset,
                    message=("1-arg jnp.where is jnp.nonzero in disguise "
                             "(data-dependent shape); use the 3-arg form "
                             "or nonzero(size=...)"),
                    source=pf.src(node.lineno)))
        elif isinstance(node, ast.Subscript):
            idx = node.slice
            elems = idx.elts if isinstance(idx, ast.Tuple) else [idx]
            for e in elems:
                if isinstance(e, (ast.Compare, ast.BoolOp)) or (
                        isinstance(e, ast.UnaryOp)
                        and isinstance(e.op, ast.Not)):
                    out.append(Finding(
                        rule="RL204", path=pf.path, line=node.lineno,
                        col=node.col_offset,
                        message=("boolean-mask indexing has a "
                                 "data-dependent shape under jit; use "
                                 "jnp.where(mask, x, fill) instead"),
                        source=pf.src(node.lineno)))
                    break
    return out


def check_reachable(files: List[ParsedFile], trace_roots,
                    graph: CallGraph = None) -> List[Finding]:
    """RL201/202/205 over code reachable from the trace roots."""
    if graph is None:
        graph = build_graph(files)
    reach: Set[str] = graph.reachable(set(trace_roots))
    out: List[Finding] = []
    for key in sorted(reach):
        fn = graph.nodes[key]
        imports = fn.pf.imports
        for node in walk_own(fn.node):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                test = node.test
                if _has_traced_call(test, imports):
                    kind = {ast.If: "if", ast.While: "while",
                            ast.IfExp: "ternary"}[type(node)]
                    out.append(Finding(
                        rule="RL201", path=fn.path, line=test.lineno,
                        col=test.col_offset,
                        message=(f"Python {kind} on a traced value in "
                                 "cohort-core-reachable code; use "
                                 "jnp.where/lax.cond"),
                        source=fn.pf.src(test.lineno), symbol=fn.qualname))
            elif isinstance(node, ast.Call):
                dotted = call_name(node, imports)
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    out.append(Finding(
                        rule="RL202", path=fn.path, line=node.lineno,
                        col=node.col_offset,
                        message=(".item() forces a host transfer in "
                                 "cohort-core-reachable code"),
                        source=fn.pf.src(node.lineno), symbol=fn.qualname))
                elif isinstance(node.func, ast.Name) and \
                        node.func.id in ("float", "int", "bool") and \
                        node.args and \
                        _has_traced_call(node.args[0], imports):
                    out.append(Finding(
                        rule="RL202", path=fn.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"{node.func.id}() on a traced value in "
                                 "cohort-core-reachable code"),
                        source=fn.pf.src(node.lineno), symbol=fn.qualname))
                elif dotted in _HOST_CALLS or (
                        dotted and dotted.startswith("numpy.")):
                    out.append(Finding(
                        rule="RL205", path=fn.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"host op {dotted} in cohort-core-"
                                 "reachable code"),
                        source=fn.pf.src(node.lineno), symbol=fn.qualname))
    return out
