"""Jaxpr-level trace-safety enforcement (RL206).

The AST rules (RL201-RL205) see the source; this pass sees what the
compiler sees. It lowers one representative round per execution path —
the fused (Pallas transmit kernel) and unfused paths of
``Trainer._step_impl`` plus a 2-round fused ``lax.scan`` via
``Trainer._run_impl`` — and walks every equation of the closed jaxpr
(recursing into scan/cond/pjit sub-jaxprs) looking for forbidden
primitives: host callbacks, host transfers, and non-static shapes. A
violation here means a hole in the compiled graph that no AST pattern
matched — the belt to the AST braces.
"""
from __future__ import annotations

from typing import List

from tools.repro_lint.findings import Finding

#: primitive names that must never appear inside a compiled round
FORBIDDEN_PRIMITIVES = {
    "pure_callback": "host callback in the compiled round body",
    "io_callback": "host io_callback in the compiled round body",
    "debug_callback": "debug callback left in the compiled round body",
    "callback": "host callback in the compiled round body",
    "device_put": "host transfer staged into the compiled round body",
    "infeed": "host infeed in the compiled round body",
    "outfeed": "host outfeed in the compiled round body",
}


def _iter_eqns(jaxpr):
    """Yield every equation of a jaxpr, recursing through sub-jaxprs
    (scan/while/cond bodies, pjit/closed_call callees, custom_* rules)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for value in eqn.params.values():
            for sub in _sub_jaxprs(value):
                yield from _iter_eqns(sub)


def _sub_jaxprs(value):
    import jax.core as jcore
    closed = getattr(jcore, "ClosedJaxpr", ())
    if isinstance(value, closed):
        yield value.jaxpr
    elif isinstance(value, jcore.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def check_jaxpr(closed_jaxpr, label: str) -> List[Finding]:
    """Scan a ClosedJaxpr for forbidden primitives and non-static shapes.

    ``label`` names the lowered path (it becomes the pseudo-path of any
    finding, e.g. ``<jaxpr:step-fused>``), so baselines can target one
    execution path without blessing the others."""
    path = f"<jaxpr:{label}>"
    out: List[Finding] = []
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    for eqn in _iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim in FORBIDDEN_PRIMITIVES:
            out.append(Finding(
                rule="RL206", path=path, line=0, col=0,
                message=(f"primitive '{prim}': "
                         f"{FORBIDDEN_PRIMITIVES[prim]}"),
                source=prim, symbol=label))
            continue
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", ())
            if not all(isinstance(dim, int) for dim in shape):
                out.append(Finding(
                    rule="RL206", path=path, line=0, col=0,
                    message=(f"primitive '{prim}' has a non-static shape "
                             f"{shape}; dynamic shapes cannot be "
                             "golden-pinned"),
                    source=prim, symbol=label))
                break
    return out


def _tiny_problem():
    """A minimal-cost instance of the shared golden problem
    (tools/update_goldens.py): same model family and config surface, small
    enough that tracing both paths stays in single-digit seconds."""
    import jax

    from jax.flatten_util import ravel_pytree

    from repro.configs.paper_models import BENCH_MLP
    from repro.data import make_federated_classification
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    x, y, _, _ = make_federated_classification(
        key, n_clients=8, per_client=8, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)   # noqa: E731
    del ravel_pytree
    return params, x, y, loss_fn


def lint_lowered_rounds() -> List[Finding]:
    """RL206 over one representative round per execution path.

    Lowers ``Trainer._step_impl`` with the fused Pallas transmit kernel
    on and off (the two numerics paths the goldens pin), plus a 2-round
    fused ``_run_impl`` so the ``lax.scan`` body itself is swept."""
    import jax

    from repro.configs import PFELSConfig
    from repro.fl import Trainer
    from repro.fl.api import replace as state_replace

    params, x, y, loss_fn = _tiny_problem()
    base = dict(num_clients=8, clients_per_round=2, local_steps=1,
                local_lr=0.05, compression_ratio=0.3, epsilon=2.0,
                rounds=2)

    out: List[Finding] = []
    for label, fused in (("step-fused", True), ("step-unfused", False)):
        cfg = PFELSConfig(**base, use_fused_kernel=fused)
        trainer = Trainer(cfg, loss_fn, params)
        state = state_replace(trainer.init(jax.random.PRNGKey(1)),
                              key=jax.random.PRNGKey(2))
        closed = jax.make_jaxpr(trainer._step_impl)(state, x, y)
        out.extend(check_jaxpr(closed, label))

    cfg = PFELSConfig(**base, use_fused_kernel=True)
    trainer = Trainer(cfg, loss_fn, params)
    state = state_replace(trainer.init(jax.random.PRNGKey(1)),
                          key=jax.random.PRNGKey(2))
    closed = jax.make_jaxpr(
        lambda s: trainer._run_impl(s, x, y, 2))(state)
    out.extend(check_jaxpr(closed, "run-scan-fused"))
    return out
