"""Shared CLI conventions for the repo's checkers.

Used by ``check_bench.py``, ``check_doc_links.py``, ``update_goldens.py``
and ``tools.repro_lint``. Deliberately jax-free so gate scripts stay cheap
to import.

Exit-code contract (mirrored from the original ``check_bench.py``):

  - ``EXIT_OK`` (0)       — clean / gate passed
  - ``EXIT_FINDINGS`` (1) — real findings or regressions
  - ``EXIT_SCHEMA`` (2)   — unusable input: malformed file, schema or
    baseline mismatch. CI treats 2 as "fix the harness", not "fix the
    code".
"""
from __future__ import annotations

import os
import sys

EXIT_OK = 0
EXIT_FINDINGS = 1
EXIT_SCHEMA = 2

#: Repository root (the directory containing ``tools/``).
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: ``src/`` layout root for ``import repro``.
SRC = os.path.join(ROOT, "src")


class ToolError(Exception):
    """Unusable input (malformed schema, bad baseline). Maps to exit 2."""

    exit_code = EXIT_SCHEMA


def add_src_to_path() -> None:
    """Make ``import repro`` work when a tool is run from the repo root."""
    if SRC not in sys.path:
        sys.path.insert(0, SRC)


def rel(path: str) -> str:
    """Repo-relative posix path for stable finding/report output."""
    return os.path.relpath(os.path.abspath(path), ROOT).replace(os.sep, "/")


def run_main(fn) -> None:
    """Run ``fn() -> int`` as a script body, mapping ToolError to exit 2."""
    try:
        raise SystemExit(fn())
    except ToolError as e:
        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(e.exit_code)
