#!/usr/bin/env python
"""Perf-regression gate over the committed bench trajectory (DESIGN.md §12).

Compares a fresh ``kernel_bench --emit`` run (the *candidate*) against the
newest committed ``benchmarks/BENCH_*.json`` (the *baseline*). The gated
quantity is the fused/oracle RATIO of each pinned row::

    ratio = us_per_call / oracle_us_per_call

Both runs measure the ratio on THEIR OWN machine, so absolute machine
speed cancels — a committed trajectory generated on a dev box still gates
a CI runner. A pinned row fails when::

    candidate_ratio > baseline_ratio * (1 + tolerance)

Usage::

    PYTHONPATH=src python tools/check_bench.py --candidate /tmp/bench.json
    ... --baseline benchmarks/BENCH_6.json --tolerance 0.25

Exit codes: 0 ok, 1 regression / missing pinned row, 2 unusable input
(schema-version mismatch, malformed file) — distinct so CI can tell "the
code got slower" from "the gate itself needs attention".
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools._cli import (EXIT_FINDINGS, EXIT_OK, EXIT_SCHEMA, ROOT,
                        ToolError)

DEFAULT_TOLERANCE = 0.25

# the schema this gate knows how to read (kept in lockstep with
# benchmarks.kernel_bench.SCHEMA_VERSION; duplicated literally so the
# gate runs without importing jax)
SCHEMA_VERSION = 1


class BenchFormatError(ToolError):
    """Input that cannot be compared (exit 2), with a remedy attached."""


def newest_baseline() -> str:
    paths = sorted(glob.glob(os.path.join(ROOT, "benchmarks",
                                          "BENCH_*.json")))
    if not paths:
        raise BenchFormatError(
            "no committed benchmarks/BENCH_*.json baseline found; generate "
            "one with `benchmarks/run.sh --emit benchmarks/BENCH_<pr>.json`"
            " and commit it")
    return paths[-1]


def load(path: str) -> dict:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise BenchFormatError(f"cannot read bench file {path}: {e}")
    ver = doc.get("schema_version")
    if ver != SCHEMA_VERSION:
        raise BenchFormatError(
            f"{path}: schema_version {ver!r} != supported {SCHEMA_VERSION}."
            f" If kernel_bench's schema moved, regenerate BOTH trajectories"
            f" with the current `benchmarks/run.sh --emit ...` and update"
            f" tools/check_bench.py's SCHEMA_VERSION in the same PR.")
    if not isinstance(doc.get("rows"), list):
        raise BenchFormatError(f"{path}: missing 'rows' list")
    return doc


def pinned_ratios(doc: dict, path: str) -> dict:
    """op -> (fused/oracle ratio, per-row tolerance or None) for every
    pinned row. A baseline row may carry a ``tolerance`` field to widen
    (or tighten) the gate for that op alone — interpret-mode rows with
    noisy Python-loop timings want a looser leash than compiled ones."""
    out = {}
    for row in doc["rows"]:
        if not row.get("pinned"):
            continue
        op, us, oracle = row.get("op"), row.get("us_per_call"), \
            row.get("oracle_us_per_call")
        if not op or not us or not oracle:
            raise BenchFormatError(
                f"{path}: pinned row {op!r} needs positive us_per_call and"
                f" oracle_us_per_call (the gate compares their ratio)")
        out[op] = (us / oracle, row.get("tolerance"))
    return out


def check(candidate: dict, baseline: dict, *, tolerance: float,
          cand_path: str = "<candidate>",
          base_path: str = "<baseline>") -> int:
    """Print the verdict per pinned row; return count of failures."""
    cand = pinned_ratios(candidate, cand_path)
    base = pinned_ratios(baseline, base_path)
    bad = 0
    for op in sorted(base):
        base_ratio, row_tol = base[op]
        if op not in cand:
            # renamed/dropped pinned rows are a hard failure: a silently
            # vanished row would freeze its regression gate forever
            print(f"FAIL {op}: pinned in baseline but missing from "
                  f"candidate (renamed or dropped? update the committed "
                  f"trajectory in the same PR)")
            bad += 1
            continue
        tol = tolerance if row_tol is None else float(row_tol)
        cand_ratio = cand[op][0]
        limit = base_ratio * (1.0 + tol)
        verdict = "FAIL" if cand_ratio > limit else "ok  "
        print(f"{verdict} {op}: ratio {cand_ratio:.3f} vs baseline "
              f"{base_ratio:.3f} (limit {limit:.3f}, tol {tol:.0%})")
        if cand_ratio > limit:
            bad += 1
    for op in sorted(set(cand) - set(base)):
        print(f"new  {op}: ratio {cand[op][0]:.3f} (no baseline yet — pin "
              f"it by refreshing the committed trajectory)")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--candidate", required=True,
                    help="fresh kernel_bench --emit JSON to vet")
    ap.add_argument("--baseline", default=None,
                    help="committed trajectory (default: newest "
                         "benchmarks/BENCH_*.json)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional ratio regression per pinned "
                         "row (default %(default)s)")
    args = ap.parse_args(argv)
    try:
        base_path = args.baseline or newest_baseline()
        cand = load(args.candidate)
        base = load(base_path)
        bad = check(cand, base, tolerance=args.tolerance,
                    cand_path=args.candidate, base_path=base_path)
    except BenchFormatError as e:
        print(f"check_bench: {e}", file=sys.stderr)
        return EXIT_SCHEMA
    print(f"{bad} pinned row(s) regressed" if bad
          else "all pinned rows within tolerance")
    return EXIT_FINDINGS if bad else EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
