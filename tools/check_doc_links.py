#!/usr/bin/env python
"""Docs reference checker (run by the CI docs job and tests/test_docs.py).

Two checks, both against the repo root this file lives under:

1. Every file path referenced from DESIGN.md / docs/paper_map.md /
   README.md (backticked tokens that look like paths with a known
   extension) resolves to a real file — tried verbatim, under src/, and
   under src/repro/.
2. Every ``DESIGN.md §N`` citation — in the Python sources across src/,
   tests/, benchmarks/, examples/, and tools/, AND in the markdown docs
   themselves (where the citation may be written ``DESIGN.md`` §N) —
   resolves to a real ``## N.`` section of DESIGN.md.

Exit status 0 when clean; prints one line per problem otherwise.
"""
from __future__ import annotations

import os
import re
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools._cli import EXIT_FINDINGS, EXIT_OK, ROOT, run_main

DOCS = ["DESIGN.md", os.path.join("docs", "paper_map.md"), "README.md"]
EXTS = (".py", ".md", ".yml", ".yaml", ".ini", ".json", ".toml")
# backticked `path/to/file.ext` (optionally with a :line or trailing /)
_PATH_RE = re.compile(r"`([A-Za-z0-9_./-]+?)/?(?::\d+)?`")
# optional closing backtick: markdown writes the citation `DESIGN.md` §N
_SECTION_RE = re.compile(r"DESIGN\.md`?\s*§(\d+)")
_HEADING_RE = re.compile(r"^##\s+(\d+)\.", re.M)
# python trees + markdown docs scanned for DESIGN §N citations
_CITATION_PY_DIRS = ("src", "tests", "benchmarks", "examples", "tools")


def _basenames():
    names = set()
    for sub in ("src", "tests", "tools", "benchmarks", "examples", "docs"):
        for _, _, files in os.walk(os.path.join(ROOT, sub)):
            names.update(files)
    names.update(f for f in os.listdir(ROOT) if os.path.isfile(
        os.path.join(ROOT, f)))
    return names


_BASENAMES = None


def _resolves(path: str) -> bool:
    for cand in {path,
                 # `pkg/module.attr` / `pkg/module.Class.method` references:
                 # strip the attribute part down to the module file
                 path.split(".")[0] + ".py" if not path.endswith(EXTS)
                 else path}:
        for base in ("", "src", os.path.join("src", "repro")):
            if os.path.exists(os.path.join(ROOT, base, cand)):
                return True
    if "/" not in path:   # bare filename (`ref.py` in a layout description)
        global _BASENAMES
        if _BASENAMES is None:
            _BASENAMES = _basenames()
        return path in _BASENAMES
    return False


def check_doc_paths():
    """-> list of 'doc: missing path' problems."""
    problems = []
    for doc in DOCS:
        full = os.path.join(ROOT, doc)
        if not os.path.exists(full):
            problems.append(f"{doc}: document itself is missing")
            continue
        text = open(full).read()
        for tok in _PATH_RE.findall(text):
            # a path reference = has a directory part or a known extension
            if not (tok.endswith(EXTS) or ("/" in tok and "." in tok)):
                continue
            if not _resolves(tok):
                problems.append(f"{doc}: referenced path `{tok}` not found")
    return problems


def check_design_sections():
    """-> list of unresolved 'DESIGN.md §N' citations across the python
    trees (src/tests/benchmarks/examples/tools) and the markdown docs."""
    design = os.path.join(ROOT, "DESIGN.md")
    sections = (set(_HEADING_RE.findall(open(design).read()))
                if os.path.exists(design) else set())

    def cited_files():
        for sub in _CITATION_PY_DIRS:
            for dirpath, _, files in os.walk(os.path.join(ROOT, sub)):
                for fname in files:
                    if fname.endswith(".py"):
                        yield os.path.join(dirpath, fname)
        for doc in DOCS:
            if os.path.exists(os.path.join(ROOT, doc)):
                yield os.path.join(ROOT, doc)

    problems = []
    for path in cited_files():
        for n in _SECTION_RE.findall(open(path).read()):
            if n not in sections:
                rel = os.path.relpath(path, ROOT)
                problems.append(
                    f"{rel}: cites DESIGN.md §{n} but DESIGN.md has no "
                    f"'## {n}.' section")
    return problems


def main() -> int:
    problems = check_doc_paths() + check_design_sections()
    for p in problems:
        print(p)
    if problems:
        print(f"{len(problems)} doc reference problem(s)")
        return EXIT_FINDINGS
    print("doc references OK")
    return EXIT_OK


if __name__ == "__main__":
    run_main(main)
