#!/usr/bin/env python
"""Golden-regression harness: pin fp32 digests of one ``Trainer.run``.

``tests/test_golden.py`` recomputes every case in ``CASES`` and compares
against the checked-in ``tests/goldens/golden_digests.json``; this tool
(re)generates or verifies that file:

    PYTHONPATH=src python tools/update_goldens.py --refresh
    PYTHONPATH=src python tools/update_goldens.py --refresh --only 'chan_*'
    PYTHONPATH=src python tools/update_goldens.py --check      # exact (==)

Why a golden tier exists (ISSUE 5): the channel-registry refactor — and
every future PR — must not *silently* move the numerics of the paper
reproduction. Each case runs two ``Trainer.run`` rounds of the shared
BENCH_MLP problem for one (algorithm × execution-path × channel-model)
point and digests the results (params sums, per-round metrics, ledger
accumulators) in float64 over the fp32 outputs, so accumulation-order
changes and PRNG-lane shifts both surface. The ``block_fading`` rows were
generated from the PRE-refactor code (PR 4 tree) and verified exact
(``--check``) against the refactored registry — the bit-identity proof of
the ``block_fading`` extraction.

Sharded-cohort cases record the device count they were generated under
(the generator forces an 8-device host platform, like
``benchmarks/kernel_bench.py``); the test skips them when the ambient
device count differs (the CI docs job runs the fast tier on 8 devices, so
they execute on every PR).
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools._cli import ROOT, add_src_to_path

if __name__ == "__main__":
    # generation always happens on the 8-device host platform so the
    # sharded cases shard for real; must win the race with jax import
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8")
    add_src_to_path()

import jax
import numpy as np

GOLDEN_PATH = os.path.join(ROOT, "tests", "goldens", "golden_digests.json")

# the shared fast-tier FL problem (mirrors tests/test_trainer_api.py BASE)
BASE = dict(num_clients=20, clients_per_round=4, local_steps=2,
            local_lr=0.05, compression_ratio=0.3, epsilon=2.0, rounds=2)
ROUNDS = 2
# the metric keys digested per round (the uniform Trainer metric contract)
METRIC_KEYS = ("train_loss", "update_norm", "beta", "energy",
               "subcarriers", "eps_round")

_AIRCOMP = ("pfels", "wfl_p", "wfl_pdp")
_ALL = ("pfels", "wfl_p", "wfl_pdp", "dp_fedavg", "fedavg")


def _cases():
    """case name -> (cfg_overrides, channel_overrides, needs_devices).

    algorithm × execution path, plus the channel-model rows (``chan_*``)
    and an error-feedback row. ``needs_devices`` > 1 marks cases whose
    digests depend on the device count (sharded cohort psum).

    PR 6 flipped ``use_fused_kernel`` to default ``True``, so every row
    that documented the old default now pins ``use_fused_kernel=False``
    explicitly — their digests are the UNCHANGED pre-flip pins (verified
    exact by ``--check`` across the flip) — and the ``*-fused*`` rows pin
    the new in-kernel mask/MRC fast path per channel model and execution
    path (fp32-parity with the unfused rows is property-tested in
    tests/test_pfels_transmit.py; the digests differ only in the last
    ulp of the accumulation order)."""
    cases = {}
    for alg in _ALL:
        cases[f"{alg}-unfused"] = (
            dict(algorithm=alg, use_fused_kernel=False), {}, 1)
        cases[f"{alg}-streamed"] = (
            dict(algorithm=alg, bank_backend="streamed",
                 use_fused_kernel=False), {}, 1)
        cases[f"{alg}-sharded"] = (
            dict(algorithm=alg, client_sharding="cohort",
                 use_fused_kernel=False), {}, 8)
    for alg in _AIRCOMP:
        # the fused Pallas path only routes aircomp aggregation
        cases[f"{alg}-fused"] = (
            dict(algorithm=alg, use_fused_kernel=True), {}, 1)
    cases["pfels-error_feedback"] = (
        dict(error_feedback=True, transmit_clip=0.5,
             use_fused_kernel=False), {}, 1)
    # the fused default on the sharded-psum path (per-shard kernel)
    cases["pfels-sharded-fused"] = (
        dict(client_sharding="cohort"), {}, 8)
    # channel-registry scenarios (pfels; block_fading is every row above)
    for backend in ("resident", "streamed"):
        tag = "" if backend == "resident" else "-streamed"
        cases[f"chan_markov{tag}"] = (
            dict(bank_backend=backend, use_fused_kernel=False),
            dict(model="markov_fading", markov_rho=0.9), 1)
        cases[f"chan_mimo_mrc{tag}"] = (
            dict(bank_backend=backend, use_fused_kernel=False),
            dict(model="mimo_mrc", num_antennas=8), 1)
        cases[f"chan_dropout{tag}"] = (
            dict(bank_backend=backend, use_fused_kernel=False),
            dict(model="dropout", dropout_prob=0.4), 1)
        # fused-default scenario rows (ISSUE 6): the in-kernel transmit
        # mask (dropout), the in-tile MRC combine (mimo_mrc, M=4), and
        # the stateful-carry fast path (markov) — pinned on both bank
        # backends so the streamed cohort loop rides the same kernel
        cases[f"chan_markov-fused{tag}"] = (
            dict(bank_backend=backend),
            dict(model="markov_fading", markov_rho=0.9), 1)
        cases[f"chan_mimo_mrc-fused{tag}"] = (
            dict(bank_backend=backend),
            dict(model="mimo_mrc", num_antennas=4), 1)
        cases[f"chan_dropout-fused{tag}"] = (
            dict(bank_backend=backend),
            dict(model="dropout", dropout_prob=0.4), 1)
    # compressor-registry rows (ISSUE 7, DESIGN.md §13): every non-default
    # registry entry × bank backend on the fused default path. The legacy
    # rand_k rows above are the bit-identity proof of the registry
    # extraction — their digests are the UNCHANGED pre-registry pins,
    # verified exact (``--check``) across the refactor.
    from repro.configs import CompressionSchedule
    for backend in ("resident", "streamed"):
        tag = "" if backend == "resident" else "-streamed"
        cases[f"comp_top_k_ef{tag}"] = (
            dict(bank_backend=backend, compressor="top_k_ef",
                 transmit_clip=0.5), {}, 1)
        cases[f"comp_threshold{tag}"] = (
            dict(bank_backend=backend, compressor="threshold",
                 threshold_frac=0.3), {}, 1)
        cases[f"comp_stoch_quant{tag}"] = (
            dict(bank_backend=backend, compressor="stoch_quant",
                 quant_bits=6, transmit_clip=0.5), {}, 1)
    # one unfused row per compressor pins the reference path the fused
    # kernel is parity-tested against (tests/test_compressors.py)
    cases["comp_top_k_ef-unfused"] = (
        dict(compressor="top_k_ef", transmit_clip=0.5,
             use_fused_kernel=False), {}, 1)
    cases["comp_stoch_quant-unfused"] = (
        dict(compressor="stoch_quant", quant_bits=6, transmit_clip=0.5,
             use_fused_kernel=False), {}, 1)
    # the sharded cohort path with an encode hook (per-shard quant keys)
    cases["comp_stoch_quant-sharded"] = (
        dict(compressor="stoch_quant", quant_bits=6, transmit_clip=0.5,
             client_sharding="cohort"), {}, 8)
    # adaptive-schedule rows: the in-graph k anneal (Support.active) and
    # the paced per-round epsilon ceiling (DESIGN.md §13)
    cases["comp_sched_linear"] = (
        dict(schedule=CompressionSchedule(mode="linear", k_end_ratio=0.5,
                                          power_end=0.7)), {}, 1)
    cases["comp_sched_budget"] = (
        dict(schedule=CompressionSchedule(mode="budget", eps_floor=0.1)),
        {}, 1)
    return cases


def _problem():
    from jax.flatten_util import ravel_pytree

    from repro.configs.paper_models import BENCH_MLP
    from repro.data import make_federated_classification
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    x, y, _, _ = make_federated_classification(
        key, n_clients=BASE["num_clients"], per_client=20, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, x, y, loss_fn, ravel_pytree


def _digest_arr(a) -> list:
    """Order-stable float64 reductions of an fp32 array — fine-grained
    enough that lane shifts AND accumulation-order changes surface."""
    a = np.asarray(a, dtype=np.float64)
    return [float(a.sum()), float(np.abs(a).sum()), float((a * a).sum())]


def run_case(name, problem):
    """One Trainer.run over the shared problem -> JSON-able digest."""
    import dataclasses

    from repro.configs import ChannelConfig, PFELSConfig
    from repro.fl import Trainer
    from repro.fl.api import replace

    params, x, y, loss_fn, ravel_pytree = _problem() if problem is None \
        else problem
    cfg_kw, chan_kw, needs_devices = _cases()[name]
    cfg = PFELSConfig(**BASE, **cfg_kw)
    if chan_kw:
        cfg = dataclasses.replace(cfg, channel=ChannelConfig(**chan_kw))
    trainer = Trainer(cfg, loss_fn, params)
    state = replace(trainer.init(jax.random.PRNGKey(1)),
                    key=jax.random.PRNGKey(2))
    if cfg.bank_backend == "streamed":
        x, y = np.asarray(x), np.asarray(y)
    end, metrics = trainer.run(state, x, y, rounds=ROUNDS)
    flat = ravel_pytree(end.params)[0]
    return {
        "needs_devices": needs_devices,
        "params": _digest_arr(flat),
        "prev_delta": _digest_arr(end.prev_delta),
        "metrics": {k: [float(v) for v in np.asarray(metrics[k],
                                                     np.float64)]
                    for k in METRIC_KEYS},
        "ledger": {"eps_sum": float(end.ledger.eps_sum),
                   "eps_max": float(end.ledger.eps_max),
                   "spends": int(end.ledger.spends)},
    }


def load_goldens() -> dict:
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--refresh", action="store_true",
                    help="regenerate digests and write the golden file")
    ap.add_argument("--check", action="store_true",
                    help="recompute and compare EXACTLY (bit-identity "
                         "verification on the generating machine)")
    ap.add_argument("--only", default=None,
                    help="comma-separated fnmatch pattern(s) restricting "
                         "--refresh/--check to matching case names (other "
                         "rows are kept)")
    args = ap.parse_args(argv)
    if args.refresh == args.check:
        ap.error("pass exactly one of --refresh / --check")

    names = sorted(_cases())
    if args.only:
        pats = args.only.split(",")
        names = [n for n in names
                 if any(fnmatch.fnmatch(n, p) for p in pats)]
    problem = _problem()

    if args.refresh:
        doc = {"meta": {"jax": jax.__version__, "rounds": ROUNDS,
                        "base": BASE, "device_count": len(jax.devices())},
               "cases": {}}
        if os.path.exists(GOLDEN_PATH):
            doc["cases"] = load_goldens()["cases"]
        # prune rows whose case no longer exists (renames/deletions must
        # not leave orphaned digests that look pinned but never run)
        for stale in sorted(set(doc["cases"]) - set(_cases())):
            print(f"pruned stale golden {stale}")
            del doc["cases"][stale]
        for name in names:
            doc["cases"][name] = run_case(name, problem)
            print(f"refreshed {name}", flush=True)
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {len(names)} cases -> {GOLDEN_PATH}")
        return 0

    golden = load_goldens()["cases"]
    bad = 0
    for name in names:
        if name not in golden:
            print(f"MISSING golden for {name}")
            bad += 1
            continue
        if golden[name]["needs_devices"] != len(jax.devices()) \
                and golden[name]["needs_devices"] > 1:
            print(f"skip {name} (needs {golden[name]['needs_devices']} "
                  f"devices)")
            continue
        got = run_case(name, problem)
        if got != golden[name]:
            print(f"DRIFT in {name}:")
            for k in golden[name]:
                if got[k] != golden[name][k]:
                    print(f"  {k}: golden={golden[name][k]} got={got[k]}")
            bad += 1
        else:
            print(f"exact {name}", flush=True)
    print(f"{bad} case(s) drifted" if bad else "all cases exact")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
