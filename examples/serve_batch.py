"""Batched serving of a pool architecture: prefill a prompt batch, decode
new tokens with the KV/SSM caches (same code paths the decode dry-run
shapes lower).

  PYTHONPATH=src python examples/serve_batch.py --arch zamba2-2.7b \
      --batch 4 --prompt-len 48 --new-tokens 24
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--full", action="store_true",
                    help="full config (shape-only sane on CPU: avoid)")
    args = ap.parse_args()
    r = serve(args.arch, reduced=not args.full, batch=args.batch,
              prompt_len=args.prompt_len, new_tokens=args.new_tokens)
    print(f"prefill {r['prefill_s']:.2f}s  decode {r['decode_s']:.2f}s  "
          f"({r['tok_per_s']:.1f} tok/s)")
    print("sample continuation:", r["tokens"][0][:12].tolist())


if __name__ == "__main__":
    main()
