"""Quickstart: PFELS end-to-end on a synthetic federated image task.

Runs a few hundred FL rounds of Algorithm 2 (simulated wireless channel,
Theorem-5 power control, client-level DP ledger) and prints the
privacy/communication/energy report.

  PYTHONPATH=src python examples/quickstart.py [--rounds 200]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
from jax.flatten_util import ravel_pytree

from repro.configs import ChannelConfig, PFELSConfig
from repro.configs.paper_models import BENCH_CNN_CIFAR
from repro.core import privacy
from repro.data import make_federated_classification
from repro.fl import evaluate, make_round_fn, round_epsilon_spent, setup
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--epsilon", type=float, default=1.5)
    ap.add_argument("--p", type=float, default=0.3)
    args = ap.parse_args()

    # fading floor scaled to the paper's operating regime at reduced d
    # (see EXPERIMENTS.md §Repro "Regime scaling")
    import math
    cfg = PFELSConfig(num_clients=100, clients_per_round=8, local_steps=5,
                      local_lr=0.05, clip=1.0, compression_ratio=args.p,
                      epsilon=args.epsilon, rounds=args.rounds,
                      momentum=0.9,
                      channel=ChannelConfig(gain_clip=(2e-3, 0.1)))
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_CNN_CIFAR)
    flat, unravel = ravel_pytree(params)
    d = flat.shape[0]
    print(f"model: {BENCH_CNN_CIFAR.name}  d={d}  "
          f"subcarriers/round={int(args.p * d)}")

    x, y, xt, yt = make_federated_classification(
        key, n_clients=cfg.num_clients, per_client=40, num_classes=10,
        image_shape=(3, 16, 16))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_CNN_CIFAR, b)
    state = setup(key, params, cfg, d)
    round_fn = make_round_fn(cfg, loss_fn, d, unravel)
    ledger = privacy.PrivacyLedger(n=cfg.num_clients,
                                   delta=cfg.resolved_delta())

    p, energy = params, 0.0
    for t in range(cfg.rounds):
        p, m = round_fn(p, state.power_limits, x, y,
                        jax.random.fold_in(key, 1000 + t))
        energy += float(m["energy"])
        ledger.spend(min(round_epsilon_spent(cfg, float(m["beta"])),
                         cfg.epsilon))
        if t % 25 == 0 or t == cfg.rounds - 1:
            tl, acc = evaluate(p, loss_fn, xt, yt)
            print(f"round {t:4d}  loss={float(m['train_loss']):.3f}  "
                  f"test_acc={acc:.3f}  beta={float(m['beta']):.2f}  "
                  f"energy={energy:.3e}")

    e_basic, d_basic = ledger.total_basic()
    e_adv, d_adv = ledger.total_advanced()
    print("\n--- PFELS report ---")
    print(f"per-round DP:       ({cfg.epsilon}, {cfg.resolved_delta():.1e})")
    print(f"T-round basic:      ({e_basic:.1f}, {d_basic:.1e})")
    print(f"T-round advanced:   ({e_adv:.1f}, {d_adv:.1e})")
    print(f"transmit energy:    {energy:.3e}")
    print(f"subcarriers/round:  {int(args.p * d)} of {d}")


if __name__ == "__main__":
    main()
