"""Quickstart: PFELS end-to-end on a synthetic federated image task.

Runs a few hundred FL rounds of Algorithm 2 (simulated wireless channel,
Theorem-5 power control, client-level DP accounting) through the unified
``Trainer``/``TrainState`` API — each evaluation chunk is one compiled
``lax.scan`` program, and the privacy ledger lives inside the compiled
state — then prints the privacy/communication/energy report.

  PYTHONPATH=src python examples/quickstart.py [--rounds 200]
"""
import argparse

import jax

from repro.configs import PFELSConfig
from repro.configs.paper_models import BENCH_CNN_CIFAR
from repro.core.channel import scaled_channel
from repro.data import make_federated_classification
from repro.fl import Trainer
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--epsilon", type=float, default=1.5)
    ap.add_argument("--p", type=float, default=0.3)
    ap.add_argument("--eval-every", type=int, default=25)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_CNN_CIFAR)
    x, y, xt, yt = make_federated_classification(
        key, n_clients=100, per_client=40, num_classes=10,
        image_shape=(3, 16, 16))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_CNN_CIFAR, b)

    d = sum(p.size for p in jax.tree.leaves(params))
    # fading floor scaled to the paper's operating regime at reduced d
    cfg = PFELSConfig(num_clients=100, clients_per_round=8, local_steps=5,
                      local_lr=0.05, clip=1.0, compression_ratio=args.p,
                      epsilon=args.epsilon, rounds=args.rounds,
                      momentum=0.9, channel=scaled_channel(d))
    trainer = Trainer(cfg, loss_fn, params)
    state = trainer.init(key)
    print(f"model: {BENCH_CNN_CIFAR.name}  d={d}  "
          f"subcarriers/round={int(args.p * d)}")

    energy = 0.0
    while int(state.round) < cfg.rounds:
        chunk = min(args.eval_every, cfg.rounds - int(state.round))
        state, m = trainer.run(state, x, y, rounds=chunk)
        energy += float(m["energy"].sum())
        tl, acc = trainer.evaluate(state, xt, yt)
        print(f"round {int(state.round):4d}  "
              f"loss={float(m['train_loss'][-1]):.3f}  "
              f"test_acc={acc:.3f}  beta={float(m['beta'][-1]):.2f}  "
              f"energy={energy:.3e}")

    totals = trainer.ledger_totals(state)   # exact, from the compiled state
    (e_basic, d_basic), (e_adv, d_adv) = totals["basic"], totals["advanced"]
    print("\n--- PFELS report ---")
    print(f"per-round DP:       ({cfg.epsilon}, {cfg.resolved_delta():.1e})")
    print(f"T-round basic:      ({e_basic:.1f}, {d_basic:.1e})")
    print(f"T-round advanced:   ({e_adv:.1f}, {d_adv:.1e})")
    print(f"transmit energy:    {energy:.3e}")
    print(f"subcarriers/round:  {int(args.p * d)} of {d}")


if __name__ == "__main__":
    main()
