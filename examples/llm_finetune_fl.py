"""Federated LLM training with PFELS as the distributed optimizer
(production mode, DESIGN.md §3): a reduced transformer from the assigned
pool trains on synthetic LM data for a few hundred steps under the PFELS
transform (clip -> rand_k mask -> power scale -> channel noise).

  PYTHONPATH=src python examples/llm_finetune_fl.py --arch phi3-mini-3.8b \
      --steps 200
"""
import argparse
import time

import jax

from repro import checkpoint
from repro.configs import PFELSConfig, reduced_config
from repro.core.channel import scaled_channel
from repro.data import make_lm_sequences
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.launch.steps import make_pfels_train_step
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--epsilon", type=float, default=4.0)
    ap.add_argument("--p", type=float, default=0.5)
    ap.add_argument("--tau", type=int, default=1,
                    help="local SGD steps per round (Alg. 2); must divide"
                         " --batch")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(key, cfg)
    d = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={d/1e6:.2f}M (~100M-scale pool variant)")

    data = make_lm_sequences(key, n_seqs=512, seq_len=args.seq + 1,
                             vocab=cfg.vocab_size)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    # fading floor scaled to the paper's regime at reduced d
    tau = args.tau
    if args.batch % tau != 0:
        tau = 1
    pfels = PFELSConfig(num_clients=1000, clients_per_round=1,
                        compression_ratio=args.p, epsilon=args.epsilon,
                        local_lr=0.1, local_steps=tau,
                        channel=scaled_channel(d))
    step = make_pfels_train_step(cfg, pfels, d, mesh)

    with use_mesh(mesh):
        step_j = jax.jit(step)
        p = params
        t0 = time.time()
        for i in range(args.steps):
            k = jax.random.fold_in(key, i)
            idx = jax.random.randint(k, (args.batch,), 0, data.shape[0])
            seqs = data[idx]
            batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
            p, m = step_j(p, batch, k)
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(m['loss']):.3f} "
                      f"beta={float(m['beta']):.2f} "
                      f"gnorm={float(m['grad_norm']):.3f}")
        print(f"{args.steps} steps in {time.time()-t0:.1f}s")
    if args.ckpt:
        checkpoint.save(args.ckpt, p, meta={"arch": cfg.name,
                                            "steps": args.steps})
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
