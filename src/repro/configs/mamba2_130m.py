"""mamba2-130m [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,            # no attention heads; SSD heads under ssm config
    n_kv_heads=1,
    d_ff=0,               # attention-free, no dense MLP
    vocab_size=50280,
    block_pattern=("mamba",),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=128),
    source="arXiv:2405.21060 (Mamba-2 SSD)",
)
