"""Architecture registry: dashed public ids -> ModelConfig, plus reduced
smoke variants (2 layers, d_model <= 512, <= 4 experts)."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.configs.command_r_35b import CONFIG as _commandr
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3
from repro.configs.qwen2_5_14b import CONFIG as _qwen25
from repro.configs.qwen2_vl_72b import CONFIG as _qwen2vl
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.zamba2_2_7b import CONFIG as _zamba2

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in (
    _qwen25, _granite, _zamba2, _stablelm, _phi3,
    _mamba2, _whisper, _commandr, _qwen3moe, _qwen2vl,
)}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


def reduced_config(name: str) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests:
    2 layers (one pattern repeat where the pattern is longer), d_model<=256,
    <=4 experts, small vocab."""
    c = get_config(name)
    d_model = min(c.d_model, 256)
    n_heads = min(c.n_heads, 4)
    n_kv = min(c.n_kv_heads, n_heads)
    head_dim = 64
    kw = dict(
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(c.d_ff, 512) if c.d_ff else 0,
        vocab_size=min(c.vocab_size, 512),
        vision_prefix=min(c.vision_prefix, 16),
        encoder_seq=min(c.encoder_seq, 32),
        n_encoder_layers=min(c.n_encoder_layers, 2),
        long_context_window=256,
    )
    if len(c.block_pattern) > 2:
        # hybrid: keep the pattern shape but shrink to one repeat of
        # (mamba, attn)
        kw["block_pattern"] = ("mamba", "attn")
        kw["n_layers"] = 2
        kw["n_repeat"] = 1
    else:
        kw["n_layers"] = 2 * len(c.block_pattern)
        kw["n_repeat"] = 2
    if c.moe is not None:
        kw["moe"] = MoEConfig(num_experts=4, top_k=2,
                              expert_ff=min(c.moe.expert_ff, 256),
                              capacity_factor=2.0)
    if c.ssm is not None:
        kw["ssm"] = SSMConfig(state_dim=min(c.ssm.state_dim, 32),
                              head_dim=32, expand=2, chunk_size=32)
    return dataclasses.replace(c, name=c.name + "-smoke", **kw)
