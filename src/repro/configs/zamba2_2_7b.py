"""zamba2-2.7b [hybrid] — Mamba2 blocks + shared attention blocks. [arXiv:2411.15242]

54 layers as 9 repeats of (5x mamba, 1x attn); the attention blocks play the
role of Zamba2's shared attention; for long_500k they run in sliding-window
mode (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    mlp_act="swiglu",
    norm="rmsnorm",
    block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "attn"),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=128),
    long_context_window=8192,
    source="arXiv:2411.15242 (Zamba2)",
)
