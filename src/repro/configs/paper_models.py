"""The paper's own model families (§8.1): modified VGG-11 on CIFAR-10
(9,750,922 params) and modified ResNet-18 on FEMNIST (11,192,746 params).

CPU-scale reduced variants (width_mult < 1, smaller images) are used by the
benchmark harness; the full configs are expressible and shape-checked.
"""
from repro.configs.base import CNNConfig

PAPER_VGG11_CIFAR10 = CNNConfig(
    name="paper-vgg11-cifar10",
    arch="vgg",
    in_channels=3,
    image_size=32,
    num_classes=10,
    width_mult=1.0,
    source="paper §8.1 (modified VGG-11, 9.75M params, CIFAR-10)",
)

PAPER_RESNET18_FEMNIST = CNNConfig(
    name="paper-resnet18-femnist",
    arch="resnet",
    in_channels=1,
    image_size=28,
    num_classes=62,
    width_mult=1.0,
    source="paper §8.1 (modified ResNet-18, 11.19M params, FEMNIST)",
)

# CPU-scale stand-ins used by benchmarks (same families, reduced width).
BENCH_CNN_CIFAR = CNNConfig(
    name="bench-vgg-small", arch="vgg", in_channels=3, image_size=16,
    num_classes=10, width_mult=0.125,
    source="reduced VGG family for CPU-scale reproduction",
)
BENCH_CNN_FEMNIST = CNNConfig(
    name="bench-resnet-small", arch="resnet", in_channels=1, image_size=14,
    num_classes=62, width_mult=0.25,
    source="reduced ResNet family for CPU-scale reproduction",
)
BENCH_MLP = CNNConfig(
    name="bench-mlp", arch="mlp", in_channels=1, image_size=8,
    num_classes=10, width_mult=1.0,
    source="tiny MLP for fast benchmark sweeps",
)
