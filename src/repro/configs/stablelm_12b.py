"""stablelm-12b [dense] — GQA. [hf:stabilityai/stablelm-2-1_6b family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    mlp_act="swiglu",
    norm="layernorm",
    block_pattern=("attn",),
    source="hf:stabilityai/stablelm-2-1_6b (family card, 12B scale point)",
)
