"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution; ViT frontend is a STUB.
[arXiv:2409.12191]

``input_specs`` supplies precomputed patch embeddings for a vision prefix of
1024 tokens (32x32 grid at one frame); the language backbone applies M-RoPE
(temporal/height/width position ids) over the prefix and 1-D positions over
text.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    rope_theta=1000000.0,
    mlp_act="swiglu",
    norm="rmsnorm",
    block_pattern=("attn",),
    vision_prefix=1024,
    source="arXiv:2409.12191 (Qwen2-VL)",
)
