"""command-r-35b [dense] — GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    qkv_bias=False,
    mlp_act="swiglu",
    norm="layernorm",
    block_pattern=("attn",),
    source="hf:CohereForAI/c4ai-command-r-v01",
)
