from repro.configs.base import (ChannelConfig, CNNConfig,
                                CompressionSchedule, ModelConfig,
                                MoEConfig, PFELSConfig, SSMConfig)
from repro.configs.registry import ARCHS, get_config, list_archs, reduced_config
from repro.configs.shapes import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES,
                                  TRAIN_4K, InputShape)

__all__ = [
    "ChannelConfig", "CNNConfig", "CompressionSchedule", "ModelConfig",
    "MoEConfig", "PFELSConfig",
    "SSMConfig", "ARCHS", "get_config", "list_archs", "reduced_config",
    "SHAPES", "InputShape", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K",
]
