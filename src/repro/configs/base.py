"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; PFELS/FL
hyper-parameters live in ``PFELSConfig``; the four assigned input shapes in
``shapes.py``. Configs are plain frozen dataclasses so they hash and can key
jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int                  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # experts are padded up to a multiple of the `model` mesh axis for
    # expert-parallel sharding; routing masks the pads.
    padded_experts: Optional[int] = None

    def experts_padded(self, model_axis: int) -> int:
        if self.padded_experts is not None:
            return self.padded_experts
        e = self.num_experts
        return ((e + model_axis - 1) // model_axis) * model_axis


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128            # N (SSD state size)
    head_dim: int = 64              # P per-head channel dim
    num_heads: Optional[int] = None  # derived: d_inner / head_dim if None
    expand: int = 2                 # d_inner = expand * d_model
    chunk_size: int = 128           # SSD chunk length (MXU-aligned)
    conv_width: int = 4             # short causal conv width
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ModelConfig:
    """One transformer-family architecture.

    ``block_pattern`` is a tuple of block kinds, repeated ``n_repeat`` times to
    form the full stack; stacked params are scanned with ``lax.scan``.
    Block kinds: "attn" (attention + dense MLP), "moe" (attention + MoE MLP),
    "mamba" (Mamba2 SSD block), "attn_only", "mlp_only".
    """
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    block_pattern: Tuple[str, ...] = ("attn",)
    n_repeat: Optional[int] = None  # default n_layers // len(block_pattern)
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False             # M-RoPE (qwen2-vl): 3-D t/h/w position ids
    sliding_window: Optional[int] = None   # if set, training attn is windowed
    long_context_window: int = 8192        # window used for long_500k decode
    mlp_act: str = "swiglu"         # swiglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False
    attn_block_kv: int = 512        # flash-attention KV block (perf knob)
    # "f32": norm computed fully in f32 (cotangents become f32 -> f32
    # backward all-reduces); "stats_f32": only the statistics in f32, the
    # scaling applied in the input dtype (bf16 cotangents; perf knob)
    norm_impl: str = "f32"
    # "2d": fsdp(data) x tensor(model); "fsdp": pure FSDP over data x model
    # (tensor parallelism off — wins when activations >> params, §Perf)
    parallelism: str = "2d"
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper): encoder consumes stub frame embeddings
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500         # stub frontend frames
    # vlm: stub vision prefix of patch embeddings
    vision_prefix: int = 0          # #patch-embedding tokens prepended
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # provenance
    source: str = ""

    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def resolved_repeat(self) -> int:
        if self.n_repeat is not None:
            return self.n_repeat
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern {self.block_pattern}")
        return self.n_layers // len(self.block_pattern)

    def with_reduced(self, **kw) -> "ModelConfig":
        """A reduced variant of the same family for CPU smoke tests."""
        return dataclasses.replace(self, **kw)

    def param_count_estimate(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS and for
        PFELS dimension d); exact counts come from the built pytree."""
        hd = self.resolved_head_dim()
        d = self.d_model
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        mlp_dense = 3 * d * self.d_ff if self.mlp_act == "swiglu" else 2 * d * self.d_ff
        total = 0
        rep = self.resolved_repeat()
        for kind in self.block_pattern:
            if kind == "attn":
                total += attn + mlp_dense
            elif kind == "moe":
                assert self.moe is not None
                e = self.moe.num_experts
                total += attn + e * 3 * d * self.moe.expert_ff + d * e
            elif kind == "mamba":
                assert self.ssm is not None
                dinner = self.ssm.expand * d
                nh = self.ssm.num_heads or dinner // self.ssm.head_dim
                # in_proj (z,x,B,C,dt) + out_proj + conv
                total += d * (2 * dinner + 2 * self.ssm.state_dim + nh) \
                    + dinner * d + self.ssm.conv_width * dinner
        total *= rep
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.is_encoder_decoder:
            enc = (attn + mlp_dense) * self.n_encoder_layers
            dec_cross = (attn) * self.n_layers     # cross-attn blocks
            total += enc + dec_cross
        return total

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count_estimate()
        full = self.param_count_estimate()
        d, e, k = self.d_model, self.moe.num_experts, self.moe.top_k
        rep = self.resolved_repeat() * sum(1 for b in self.block_pattern if b == "moe")
        expert_params = rep * e * 3 * d * self.moe.expert_ff
        active_expert = rep * k * 3 * d * self.moe.expert_ff
        return full - expert_params + active_expert


@dataclass(frozen=True)
class CNNConfig:
    """Paper's own model families (VGG-11 on CIFAR-10, ResNet-18 on FEMNIST),
    reduced-scale capable for CPU reproduction."""
    name: str
    arch: str                        # "vgg" | "resnet" | "mlp"
    in_channels: int = 3
    image_size: int = 32
    num_classes: int = 10
    width_mult: float = 1.0
    source: str = ""


@dataclass(frozen=True)
class ChannelConfig:
    """Wireless channel scenario (paper §8.1 + the DESIGN.md §11 registry).

    ``model`` selects a registered :mod:`repro.core.channels` entry —
    ``block_fading`` is the paper's flat block-fading MAC (the default and
    the bit-exact seed behavior); ``markov_fading`` correlates gains across
    rounds (Gauss–Markov copula, ``markov_rho``); ``mimo_mrc`` gives the
    base station ``num_antennas`` receive antennas with maximum-ratio
    combining; ``dropout`` wraps ``dropout_base`` and zeroes a
    Bernoulli(``dropout_prob``) subset of the cohort's transmissions.
    Model-specific fields are ignored by models that don't read them.
    """
    gain_mean: float = 0.02           # |h| ~ Exp(mean)
    gain_clip: Tuple[float, float] = (1e-4, 0.1)
    noise_std: float = 1.0            # sigma_0
    snr_db_range: Tuple[float, float] = (2.0, 15.0)  # per-device max SNR
    # imperfect CSI (beyond paper — the paper defers this to future work):
    # clients precompensate with h_est = h * (1 + eps), eps ~ N(0, csi_err^2)
    csi_error: float = 0.0
    # --- scenario selection (DESIGN.md §11) ---
    model: str = "block_fading"       # repro.core.channels registry key
    markov_rho: float = 0.9           # AR(1) round-to-round gain correlation
    num_antennas: int = 4             # M receive antennas (mimo_mrc)
    dropout_prob: float = 0.1         # P(client drops its transmission)
    dropout_base: str = "block_fading"  # model the dropout wrapper fades by

    def __post_init__(self):
        """Reject silently-NaN configurations up front: a swapped
        ``gain_clip`` used to clamp every gain to the lower bound and feed
        a nonsensical β design; a non-positive ``noise_std`` makes C2 (and
        the ε ledger) undefined."""
        lo, hi = self.gain_clip
        if not (0.0 < lo < hi):
            raise ValueError(
                f"gain_clip must satisfy 0 < lo < hi, got {self.gain_clip}")
        if self.gain_mean <= 0.0:
            raise ValueError(f"gain_mean must be > 0, got {self.gain_mean}")
        if self.noise_std <= 0.0:
            raise ValueError(
                f"noise_std (sigma_0) must be > 0, got {self.noise_std}")
        s_lo, s_hi = self.snr_db_range
        if not s_lo < s_hi:
            raise ValueError(
                f"snr_db_range must be ordered (lo < hi), got "
                f"{self.snr_db_range}")
        if self.csi_error < 0.0:
            raise ValueError(
                f"csi_error must be >= 0, got {self.csi_error}")
        if not self.model or not isinstance(self.model, str):
            raise ValueError(f"model must be a registry name, got "
                             f"{self.model!r}")
        if not 0.0 <= self.markov_rho < 1.0:
            raise ValueError(
                f"markov_rho must be in [0, 1), got {self.markov_rho}")
        if self.num_antennas < 1:
            raise ValueError(
                f"num_antennas must be >= 1, got {self.num_antennas}")
        if not 0.0 <= self.dropout_prob < 1.0:
            raise ValueError(
                f"dropout_prob must be in [0, 1), got {self.dropout_prob}")
        if self.dropout_base == "dropout":
            raise ValueError("dropout_base cannot be 'dropout' (no "
                             "self-nesting); pick a fading model")


@dataclass(frozen=True)
class CompressionSchedule:
    """DP-aware adaptive compression schedule (DESIGN.md §13).

    Declarative policy; ``repro.core.compressors.schedules`` evaluates it
    trace-safely inside the compiled scan from the round counter and the
    ledger's running ε spend (``Trainer.run`` stays zero-host-round-trip).

    ``mode``: "none" (the seed-exact default — every knob untouched),
    "linear" (k budget and transmit power annealed linearly over
    ``cfg.rounds``), or "budget" (same anneals, plus the per-round ε
    ceiling becomes the remaining total budget ``cfg.epsilon·cfg.rounds``
    spread over the rounds left, floored at ``eps_floor`` and never above
    ``cfg.epsilon``). ``k_end_ratio``: final live fraction of the k
    budget at round T (1.0 = no k anneal). ``power_end``: final P_i
    multiplier at round T (1.0 = no power anneal).
    """
    mode: str = "none"            # none | linear | budget
    k_end_ratio: float = 1.0      # final live k fraction at round T
    power_end: float = 1.0        # final power-limit multiplier at T
    eps_floor: float = 0.0        # budget mode: per-round eps floor

    def __post_init__(self):
        if self.mode not in ("none", "linear", "budget"):
            raise ValueError(
                f"schedule mode must be none|linear|budget, got "
                f"{self.mode!r}")
        if not 0.0 < self.k_end_ratio <= 1.0:
            raise ValueError(
                f"k_end_ratio must be in (0, 1], got {self.k_end_ratio}")
        if not 0.0 < self.power_end <= 1.0:
            raise ValueError(
                f"power_end must be in (0, 1] (anneal down), got "
                f"{self.power_end}")
        if self.eps_floor < 0.0:
            raise ValueError(
                f"eps_floor must be >= 0, got {self.eps_floor}")


@dataclass(frozen=True)
class PFELSConfig:
    """Algorithm 2 hyper-parameters."""
    num_clients: int = 1000           # N
    clients_per_round: int = 32       # r
    local_steps: int = 5              # tau (paper uses tau epochs; we expose steps)
    local_lr: float = 0.05            # eta
    clip: float = 1.0                 # C1 (per-step gradient clip)
    compression_ratio: float = 0.3    # p = k/d
    epsilon: float = 1.5              # per-round privacy budget
    delta: Optional[float] = None     # default 1/N
    rounds: int = 2000                # T
    momentum: float = 0.9
    algorithm: str = "pfels"          # pfels | wfl_p | wfl_pdp | dp_fedavg | fedavg
    unbiased_rescale: bool = False    # beyond-paper: multiply update by d/k
    error_feedback: bool = False      # beyond-paper: error compensation [28-30]
    dp_fedavg_sigma: float = 1.0      # noise multiplier for DP-FedAvg baseline
    # exact | mask (seeded Bernoulli(p)) | server_topk (beyond paper:
    # omega_t = top-k coords of |Delta_hat_{t-1}| — server-guided, keeps
    # the shared-subcarrier alignment AirComp requires)
    randk_mode: str = "exact"
    grad_accum: int = 1               # microbatches per step (memory knob)
    # fused transmit pipeline — THE DEFAULT execution mode (DESIGN.md
    # §12): route AirComp aggregation through the kernels/pfels_transmit
    # Pallas path (clip -> rand_k -> power scale -> transmit mask ->
    # MRC combine -> noisy AirComp sum in one pass over d-tiles, no
    # (r, d) intermediates), for EVERY registered channel model and both
    # execution paths (vmapped and sharded-psum). use_fused_kernel=False
    # is the explicit escape hatch back to the unfused pure-JAX oracle
    # (the pre-PR-6 default; fp32-parity enforced by
    # tests/test_pfels_transmit.py and the golden tier).
    use_fused_kernel: bool = True
    # optional transmit-side per-client l2 cap C: each Delta_i is scaled by
    # min(1, C/||Delta_i||) before sparsification, enforcing the Theorem-5
    # premise ||Delta|| <= eta tau C1. None disables.
    transmit_clip: Optional[float] = None
    # sharded cohort execution (DESIGN.md §7): "cohort" runs the per-client
    # pipeline under shard_map with the r selected clients partitioned over
    # the ("pod", "data") mesh axes and the AirComp sum as a cross-device
    # psum; "none" keeps the vmapped single-device path. The cohort mode
    # drops back to the vmapped path whenever the mesh's client extent is 1
    # or does not divide clients_per_round (graceful replication).
    client_sharding: str = "none"     # none | cohort
    # ClientBank backend (DESIGN.md §10): "resident" keeps all per-client
    # state (EF residuals, PRNG lanes, participation counts) as dense
    # device arrays carried through the scan — bit-identical to the
    # pre-bank behavior. "streamed" keeps the bank host-side and moves
    # only the sampled r-client cohort on/off device each round, so
    # device memory is independent of num_clients (the population-scale
    # path; benchmarks/population_scale.py runs 100_000 clients).
    bank_backend: str = "resident"    # resident | streamed
    # update-compression scheme (DESIGN.md §13): a repro.core.compressors
    # registry key. "rand_k" is the paper's uniform draw (seed-exact);
    # "top_k_ef" transmits the top coords of the released aggregate with
    # mandatory error feedback; "threshold" hard-thresholds against
    # threshold_frac * max|prev_delta| (static-width padded, live slots
    # via Support.active); "stoch_quant" adds quant_bits-level unbiased
    # stochastic quantization over rand-k with its own sensitivity bound.
    # Consumed only by sparsifying AirComp algorithms (pfels).
    compressor: str = "rand_k"
    quant_bits: int = 8               # stoch_quant magnitude levels 2^(b-1)-1
    threshold_frac: float = 0.1       # threshold: fraction of max|prev_delta|
    # adaptive k / power / per-round-eps schedule (DESIGN.md §13);
    # mode="none" is the seed-exact static default
    schedule: CompressionSchedule = field(
        default_factory=CompressionSchedule)
    channel: ChannelConfig = field(default_factory=ChannelConfig)

    def resolved_delta(self) -> float:
        return self.delta if self.delta is not None else 1.0 / self.num_clients
