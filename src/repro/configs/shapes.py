"""The four assigned input shapes.

Decode shapes lower ``serve_step`` (one new token with a KV cache of
``seq_len``); ``prefill_32k`` lowers ``prefill_step``; ``train_4k`` lowers the
PFELS ``train_step``.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
