"""granite-moe-3b-a800m [moe] — 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                      # per-expert FFN width
    vocab_size=49155,
    mlp_act="swiglu",
    norm="rmsnorm",
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=40, top_k=8, expert_ff=512, padded_experts=48),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (family card, 3b-a800m scale point)",
)
