"""whisper-tiny [audio] — enc-dec; conv/mel frontend is a STUB. [arXiv:2212.04356]

``input_specs`` supplies precomputed frame embeddings (batch, 1500, d_model)
for the encoder; we implement the transformer backbone only.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,               # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_act="gelu",
    norm="layernorm",
    block_pattern=("attn",),
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq=1500,
    rope_theta=0.0,           # whisper uses learned absolute positions
    source="arXiv:2212.04356 (Whisper)",
)
