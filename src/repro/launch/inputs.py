"""ShapeDtypeStruct input specs per (architecture x input shape) — the
shape-only stand-ins used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import transformer as T
from repro.models.attention import kv_cache_spec


def _sds(shape, dtype, mesh: Optional[Mesh], spec: Optional[Tuple] = None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    from repro.sharding.rules import resolve_spec
    ps = resolve_spec(spec or (None,) * len(shape), shape, mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, ps))


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      mesh: Optional[Mesh] = None) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {}
    s_text = s - cfg.vision_prefix if cfg.family == "vlm" else s
    batch["tokens"] = _sds((b, s_text), jnp.int32, mesh, ("batch", None))
    batch["labels"] = _sds((b, s_text), jnp.int32, mesh, ("batch", None))
    if cfg.family == "vlm":
        batch["vision_embeds"] = _sds((b, cfg.vision_prefix, cfg.d_model),
                                      dt, mesh, ("batch", None, None))
    if cfg.is_encoder_decoder:
        batch["audio_embeds"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                                     dt, mesh, ("batch", None, None))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape,
                        mesh: Optional[Mesh] = None) -> Dict:
    specs = train_batch_specs(cfg, shape, mesh)
    specs.pop("labels")
    return specs


def decode_specs(cfg: ModelConfig, shape: InputShape,
                 mesh: Optional[Mesh] = None,
                 window: Optional[int] = None) -> Dict:
    """Token + cache specs for serve_step: ONE new token, cache of seq_len
    (ring-buffer of `window` slots for sliding-window/long-context mode)."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    caches_shape = jax.eval_shape(
        lambda: T.make_caches(cfg, b, s, window=window, dtype=dt))

    from repro.sharding.rules import resolve_spec

    def shard_cache_leaf(path, sd):
        if mesh is None:
            return jax.ShapeDtypeStruct(sd.shape, sd.dtype)
        name = str(getattr(path[-1], "key", ""))
        if name in ("k", "v"):      # stacked (rep, B, S, Hkv, Dh)
            ps = P(None, *kv_cache_spec(sd.shape[1:], mesh))
        elif name in ("ssm", "conv"):  # stacked (rep, B, ...) state
            ps = resolve_spec((None, "batch") + (None,) * (sd.ndim - 2),
                              sd.shape, mesh)
        else:                       # idx / slot_pos scalars
            ps = resolve_spec((None,) * sd.ndim, sd.shape, mesh)
        return jax.ShapeDtypeStruct(sd.shape, sd.dtype,
                                    sharding=NamedSharding(mesh, ps))

    caches = jax.tree_util.tree_map_with_path(shard_cache_leaf, caches_shape)
    out = {"token": _sds((b, 1), jnp.int32, mesh, ("batch", None)),
           "caches": caches}
    if cfg.is_encoder_decoder:
        out["enc_out"] = _sds((b, cfg.encoder_seq, cfg.d_model), dt, mesh,
                              ("batch", None, None))
    return out


def _batch_div(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n


def long_context_window(cfg: ModelConfig, shape: InputShape
                        ) -> Optional[int]:
    """Sliding-window policy for the long_500k shape (DESIGN.md §5)."""
    if shape.name != "long_500k":
        return None
    if cfg.family == "ssm":
        return None            # attention-free
    return cfg.long_context_window
