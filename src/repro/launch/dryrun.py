import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, record roofline terms.

Loop-corrected costs: XLA's cost_analysis counts each while-loop body ONCE,
so a scanned 32-layer stack or a 64-block flash-attention loop is
undercounted. We therefore derive FLOPs / bytes / collective-bytes from our
own HLO cost model (repro.launch.hlo_cost) which walks the compiled module's
call graph and multiplies each computation by its enclosing while-loop trip
counts (validated against analytic counts in tests/test_hlo_cost.py). The
raw cost_analysis() numbers are recorded alongside for reference.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import PFELSConfig
from repro.launch import inputs as I
from repro.launch import steps as S
from repro.launch.hlo_analysis import (collective_bytes, model_flops,
                                       normalize_cost as
                                       hlo_analysis_normalize,
                                       roofline_terms)
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import transformer as T
from repro.sharding.rules import tree_shardings


def _param_shardings(cfg, mesh):
    with use_mesh(mesh):
        shapes = T.init_shapes(cfg)
        logical = T.logical_axes(cfg)
    return shapes, tree_shardings(mesh, logical, shapes)


def _with_sharding(shapes, shardings):
    return jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        shapes, shardings)


def _reduce_rep(cfg, rep: int):
    kw = dict(n_repeat=rep, n_layers=rep * len(cfg.block_pattern))
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = rep
    return dataclasses.replace(cfg, **kw)


def lower_and_compile(cfg, shape, mesh, pfels, *, donate=True):
    """Build + lower + compile the step for (cfg, shape) on mesh.
    Returns (compiled, tokens_processed)."""
    param_shapes, param_sh = _param_shardings(cfg, mesh)
    params_in = _with_sharding(param_shapes, param_sh)
    n_params = sum(x.size for x in jax.tree.leaves(param_shapes))

    n_pods = mesh.shape.get("pod", 1)
    with use_mesh(mesh):
        if shape.kind == "train":
            batch = I.train_batch_specs(cfg, shape, mesh)
            step = S.make_pfels_train_step(cfg, pfels, n_params, mesh)
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            if n_pods > 1:
                # explicit client dim of model replicas, sharded over 'pod'
                c_shapes = S.clientize_shapes(param_shapes, n_pods)
                c_logical = S.clientize_logical(T.logical_axes(cfg), n_pods)
                params_in = _with_sharding(
                    c_shapes, tree_shardings(mesh, c_logical, c_shapes))
            jitted = jax.jit(step, donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(params_in, batch, key)
            tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            batch = I.prefill_batch_specs(cfg, shape, mesh)
            step = S.make_prefill_step(cfg)
            lowered = jax.jit(step).lower(params_in, batch)
            tokens = shape.global_batch * shape.seq_len
        else:  # decode
            window = I.long_context_window(cfg, shape)
            spec = I.decode_specs(cfg, shape, mesh, window=window)
            step = S.make_serve_step(cfg, window=window)
            kwargs = {}
            if cfg.is_encoder_decoder:
                kwargs["enc_out"] = spec["enc_out"]
            jitted = jax.jit(step, donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_in, spec["token"], spec["caches"],
                                   **kwargs)
            tokens = shape.global_batch
        compiled = lowered.compile()
    return compiled, tokens, n_params




# §Perf-validated optimized variants (EXPERIMENTS.md §Perf): applied by
# `--perf`. Baseline tables always use the plain configs.
PERF_VARIANTS = {
    # dense-family train shapes: activation collectives >> weight
    # collectives at <= ~4B params -> pure FSDP + larger flash block
    ("phi3-mini-3.8b", "train_4k"): dict(parallelism="fsdp",
                                         attn_block_kv=1024),
    ("mamba2-130m", "train_4k"): dict(parallelism="fsdp"),
    # memory-bound 32k prefill: quarter the flash accumulator round-trips
    ("qwen2.5-14b", "prefill_32k"): dict(attn_block_kv=2048),
}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               pfels: PFELSConfig = None, verbose: bool = True,
               analyze_loops: bool = True, perf: bool = False):
    cfg = get_config(arch)
    if perf and (arch, shape_name) in PERF_VARIANTS:
        cfg = dataclasses.replace(cfg, **PERF_VARIANTS[(arch, shape_name)])
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    # fleet of 1000 edge sites (paper §8.1); the pods of this mesh are the
    # sites participating this round. grad_accum bounds activation memory
    # for the widest models (see EXPERIMENTS.md §Perf).
    accum = 4 if cfg.d_model >= 8192 else (
        2 if (cfg.d_model >= 5120 or cfg.moe is not None) else 1)
    if cfg.family == "hybrid":
        accum = max(accum, 2)   # SSD chunk intermediates (80 heads)
    if multi_pod:
        if cfg.moe is not None:
            # per-pod MoE dispatch buffers under the client vmap
            accum = 8 if cfg.moe.num_experts >= 64 else 4
        elif cfg.d_model >= 8192:
            accum = 8
    # local_steps=1 for the baseline tables (tau > 1 is supported — see
    # tests/test_system.py and the tau datapoint in EXPERIMENTS.md §Perf)
    pfels = pfels or PFELSConfig(compression_ratio=0.3, epsilon=1.5,
                                 num_clients=1000, local_steps=1,
                                 clients_per_round=mesh.shape.get("pod", 1),
                                 grad_accum=accum)

    import contextlib
    from repro.sharding.rules import PURE_FSDP, logical_overrides
    par_ctx = (logical_overrides(PURE_FSDP) if cfg.parallelism == "fsdp"
               else contextlib.nullcontext())

    t0 = time.time()
    with par_ctx:
        compiled, tokens, n_params = lower_and_compile(cfg, shape, mesh,
                                                       pfels)
    t1 = time.time()
    mem = compiled.memory_analysis()
    raw_cost = hlo_analysis_normalize(compiled.cost_analysis())
    raw_coll = collective_bytes(compiled.as_text())

    if analyze_loops:
        corrected = analyze_hlo(compiled.as_text())
        corrected.setdefault("flops", 0.0)
        corrected.setdefault("bytes", 0.0)
        corrected.setdefault("coll", 0.0)
    else:
        corrected = {"flops": float(raw_cost.get("flops", 0.0)),
                     "bytes": float(raw_cost.get("bytes accessed", 0.0)),
                     "coll": float(raw_coll["total"])}
    t2 = time.time()

    terms = roofline_terms({"flops": corrected["flops"],
                            "bytes accessed": corrected["bytes"]},
                           {"total": corrected["coll"]}, n_chips)

    n_active = cfg.active_param_count_estimate()
    mf = model_flops(n_active, tokens,
                     "train" if shape.kind == "train" else "serve")
    mf_per_device = mf / n_chips
    useful = (mf_per_device / terms["hlo_flops_per_device"]
              if terms["hlo_flops_per_device"] else 0.0)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "n_chips": n_chips,
        "n_params": int(n_params),
        "step_kind": shape.kind,
        "compile_s": round(t1 - t0, 2),
        "analysis_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        "raw_cost": {"flops": float(raw_cost.get("flops", 0.0)),
                     "bytes": float(raw_cost.get("bytes accessed", 0.0)),
                     "coll": raw_coll["total"],
                     "collective_counts": raw_coll["counts"]},
        "corrected_cost": {k: float(v) for k, v in corrected.items()},
        "roofline": terms,
        "model_flops_per_device": mf_per_device,
        "useful_flops_ratio": useful,
    }
    if verbose:
        gb = 1 << 30
        print(f"[{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}]"
              f" compile={record['compile_s']}s"
              f" mem/dev={record['memory']['peak_bytes_per_device']/gb:.2f}GiB"
              f" t_comp={terms['t_compute_s']*1e3:.2f}ms"
              f" t_mem={terms['t_memory_s']*1e3:.2f}ms"
              f" t_coll={terms['t_collective_s']*1e3:.2f}ms"
              f" dom={terms['dominant']}"
              f" useful={useful:.2f}", flush=True)
    return record


def dryrun_cohort(*, clients_per_round: int = 32, verbose: bool = True):
    """Lower + compile the sharded FL round (client_sharding="cohort",
    DESIGN.md §7) through the Trainer API on a cohort mesh carved from the
    forced host devices: sanity-checks that the shard_map round lowers at
    pod scale and records its compile/memory numbers like the model
    dry-runs."""
    from repro.configs.paper_models import BENCH_MLP
    from repro.data import make_federated_classification
    from repro.fl import Trainer
    from repro.launch.mesh import make_cohort_mesh
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    x, y, _, _ = make_federated_classification(
        key, n_clients=1000, per_client=30, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    cfg = PFELSConfig(num_clients=1000, clients_per_round=clients_per_round,
                      local_steps=1, client_sharding="cohort")
    mesh = make_cohort_mesh(cfg.clients_per_round)
    shards = mesh.shape["pod"] * mesh.shape["data"]
    trainer = Trainer(cfg, loss_fn, params, mesh=mesh)
    d = trainer.d
    state = trainer.init(jax.random.PRNGKey(1))

    t0 = time.time()
    lowered = trainer.step.lower(state, x, y)
    compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    record = {
        "kind": "cohort_round", "d": int(d),
        "clients_per_round": cfg.clients_per_round,
        "mesh": dict(mesh.shape), "shards": shards,
        "compile_s": round(t1 - t0, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
    }
    if verbose:
        gb = 1 << 30
        print(f"[cohort round r={cfg.clients_per_round} x "
              f"{dict(mesh.shape)}] compile={record['compile_s']}s"
              f" mem/dev="
              f"{record['memory']['peak_bytes_per_device']/gb:.3f}GiB"
              f" shards={shards}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cohort", action="store_true",
                    help="dry-run the sharded FL round (client_sharding="
                         "'cohort') instead of a model x shape combination")
    ap.add_argument("--cohort-r", type=int, default=32,
                    help="clients per round for --cohort")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-loop-analysis", action="store_true")
    ap.add_argument("--perf", action="store_true",
                    help="apply EXPERIMENTS.md §Perf optimized variants")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.cohort:
        rec = dryrun_cohort(clients_per_round=args.cohort_r)
        path = os.path.join(args.out, f"cohort_round__r{args.cohort_r}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print("cohort dry-run OK")
        return
    jobs = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                jobs.append((a, s))
    else:
        jobs.append((args.arch, args.shape))

    failures = []
    for arch, shape in jobs:
        try:
            rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                             analyze_loops=not args.no_loop_analysis,
                             perf=args.perf)
            tag = "multipod" if args.multi_pod else "pod"
            if args.perf:
                tag += "_perf"
            path = os.path.join(args.out, f"{arch}__{shape}__{tag}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"dry-run OK: {len(jobs)} combination(s)")


if __name__ == "__main__":
    main()
