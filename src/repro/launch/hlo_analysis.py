"""Roofline-term extraction from the compiled dry-run artifact.

compute  = HLO_FLOPs(per device) / peak_FLOP/s
memory   = HLO_bytes(per device) / HBM_bw
collective = per-device collective bytes (ring model per op kind) / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (system prompt).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved over ICI, ring-model per collective kind."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        res_bytes = _shape_bytes(m.group(1))
        kind = m.group(2)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            moved = 2.0 * frac * res_bytes
        elif kind == "all-gather":
            moved = frac * res_bytes
        elif kind == "reduce-scatter":
            moved = frac * res_bytes * g      # input = result * g
        elif kind == "all-to-all":
            moved = frac * res_bytes
        else:  # collective-permute
            moved = float(res_bytes)
        out[kind] += moved
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def normalize_cost(cost) -> Dict:
    """``Compiled.cost_analysis()`` returns a dict on jax >= 0.5 but a
    one-element list of dicts on 0.4.x — accept both."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def roofline_terms(cost: Dict, coll: Dict, n_chips: int) -> Dict:
    cost = normalize_cost(cost)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll["total"] / LINK_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll["total"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "n_chips": n_chips,
    }


def model_flops(n_active_params: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D for a train step (fwd+bwd), 2*N*D for inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens
