"""Production step functions.

``make_pfels_train_step``: PFELS as a distributed optimizer at pod scale
(DESIGN.md §3) — each pod is one FL client. Multi-pod uses an EXPLICIT
client dimension: every param carries a leading (n_pods,) dim sharded over
`pod` (client model replicas), the forward/backward is vmapped with
``spmd_axis_name="pod"`` so per-client gradients never cross pods, and the
AirComp superposition is the sum over the client dim — GSPMD lowers it to
the cross-pod all-reduce. This is pure auto-sharding (no manual regions).

``make_prefill_step`` / ``make_serve_step``: plain forwards of the model
stack (PFELS applies to training only).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, PFELSConfig
from repro.core import aggregation, channel, power_control, randk
from repro.core.clipping import clip_by_global_norm
from repro.models import transformer as T


def _round_channel(key, pfels: PFELSConfig, d: int, n_clients: int):
    """Per-round channel state + Theorem-5 beta (same on every client)."""
    kg, kp = jax.random.split(key)
    gains = channel.sample_gains(kg, n_clients, pfels.channel)
    p_lims = channel.sample_power_limits(kp, n_clients, d, pfels.channel)
    k_coords = max(int(round(pfels.compression_ratio * d)), 1)
    beta = power_control.beta_pfels(
        gains, p_lims, d=d, k=k_coords, c1=pfels.clip, eta=pfels.local_lr,
        tau=max(pfels.local_steps, 1), epsilon=pfels.epsilon, r=n_clients,
        n=max(pfels.num_clients, n_clients), delta=pfels.resolved_delta(),
        sigma0=pfels.channel.noise_std)
    return gains, beta


def make_pfels_train_step(cfg: ModelConfig, pfels: PFELSConfig, d: int,
                          mesh: Mesh, *, remat: bool = True):
    """Returns step(params, batch, key) -> (params, metrics).

    Multi-pod: params carry a leading client dim (see module docstring);
    use `clientize_*` helpers to build inputs.
    """
    n_clients = mesh.shape.get("pod", 1)
    sigma0 = pfels.channel.noise_std

    def loss_fn(p, b):
        return T.forward_train(p, cfg, b, remat=remat)

    accum = max(pfels.grad_accum, 1)
    tau = max(pfels.local_steps, 1)

    def local_update(params, batch, *, metrics_only=False):
        """Per-client local update Delta_i.

        tau == 1: Delta = -eta * clip(grad)  (with grad_accum microbatching)
        tau > 1:  Alg. 2 lines 6-10 at pod scale — tau clipped-SGD steps,
        each on a 1/tau slice of the client's batch; Delta = theta_tau -
        theta_0 (sensitivity eta*tau*C1 exactly as Lemma 2)."""
        if tau == 1:
            (loss, metrics), grads = grads_of(params, batch)
            g_clip, gnorm = clip_by_global_norm(grads, pfels.clip)
            delta = jax.tree.map(lambda g: -pfels.local_lr
                                 * g.astype(jnp.float32), g_clip)
            return delta, loss, metrics, gnorm

        b0 = jax.tree.leaves(batch)[0].shape[0]
        if b0 % tau != 0:
            raise ValueError(
                f"PFELS local_steps={tau} must divide the per-client batch "
                f"{b0} (each local step trains on one 1/tau slice)")
        mb = jax.tree.map(
            lambda x: x.reshape((tau, x.shape[0] // tau) + x.shape[1:]),
            batch)

        def body(p, b_s):
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b_s)
            g, gnorm = clip_by_global_norm(g, pfels.clip)
            p = jax.tree.map(
                lambda p_, g_: (p_.astype(jnp.float32) - pfels.local_lr
                                * g_.astype(jnp.float32)).astype(p_.dtype),
                p, g)
            return p, (loss, m, gnorm)

        p_tau, (losses, ms, gnorms) = jax.lax.scan(body, params, mb)
        delta = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            p_tau, params)
        metrics = jax.tree.map(jnp.mean, ms)
        return delta, jnp.mean(losses), metrics, jnp.mean(gnorms)

    def grads_of(params, batch):
        """(loss, metrics), grads — with `accum` microbatches scanned to
        bound activation memory (per-layer carry stacks shrink by accum)."""
        if accum == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        mb = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def body(acc, b_i):
            out = jax.value_and_grad(loss_fn, has_aux=True)(params, b_i)
            acc = jax.tree.map(lambda a, x: a + x.astype(a.dtype), acc, out)
            return acc, None

        first = jax.tree.map(lambda x: x[0], mb)
        rest = jax.tree.map(lambda x: x[1:], mb)
        init = jax.value_and_grad(loss_fn, has_aux=True)(params, first)
        acc, _ = jax.lax.scan(body, init, rest)
        return jax.tree.map(lambda x: (x / accum).astype(x.dtype), acc)

    if n_clients == 1:
        def step(params, batch, key):
            update, loss, metrics, gnorm = local_update(params, batch)
            kc, km, kn = jax.random.split(key, 3)
            gains, beta = _round_channel(kc, pfels, d, 1)
            masks = randk.mask_tree(km, update, pfels.compression_ratio)
            delta = aggregation.pfels_production_aggregate(
                update, masks, beta=beta, r=1, sigma0=sigma0, noise_key=kn,
                axis_name=None, unbiased_rescale=pfels.unbiased_rescale,
                compression_p=pfels.compression_ratio)
            new_params = jax.tree.map(
                lambda p_, u: (p_.astype(jnp.float32)
                               + u.astype(jnp.float32)).astype(p_.dtype),
                params, delta)
            masked = randk.apply_mask_tree(update, masks)
            sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                     for x in jax.tree.leaves(masked))
            energy = (beta / gains[0]) ** 2 * sq
            return new_params, dict(metrics, loss=loss, beta=beta,
                                    grad_norm=gnorm, energy=energy)
        return step

    # ---------------- multi-pod: explicit client dim -------------------
    def step(params_c, batch, key):
        b_global = jax.tree.leaves(batch)[0].shape[0]
        b_local = b_global // n_clients
        batch_c = jax.tree.map(
            lambda x: x.reshape((n_clients, b_local) + x.shape[1:]), batch)

        from repro.sharding.rules import exclude_axes

        def client_fn(p, b):
            with exclude_axes("pod"):
                return local_update(p, b)

        updates_c, losses, metrics, gnorms = jax.vmap(
            client_fn, spmd_axis_name="pod")(params_c, batch_c)

        kc, km, kn = jax.random.split(key, 3)
        gains, beta = _round_channel(kc, pfels, d, n_clients)

        # shared A^t: one mask tree, broadcast over the client dim
        template = jax.tree.map(lambda x: x[0], updates_c)
        masks = randk.mask_tree(km, template, pfels.compression_ratio)
        masked_c = jax.tree.map(
            lambda u, m: u * m.astype(u.dtype)[None], updates_c, masks)

        # AirComp: sum over the client dim == cross-pod all-reduce;
        # channel gains are pre-inverted so the superposed signal is
        # beta * sum_i A Delta_i, then intrinsic noise is added.
        leaves, treedef = jax.tree.flatten(
            jax.tree.map(lambda u: jnp.sum(u * beta, axis=0), masked_c))
        mask_leaves = jax.tree.leaves(masks)
        keys = jax.random.split(kn, len(leaves))
        noisy = [x + sigma0 * m.astype(x.dtype)
                 * jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype)
                 for x, m, k in zip(leaves, mask_leaves, keys)]
        scale = 1.0 / (n_clients * beta)
        if pfels.unbiased_rescale:
            scale = scale / pfels.compression_ratio
        delta = jax.tree.map(lambda x: x * scale,
                             jax.tree.unflatten(treedef, noisy))

        new_params = jax.tree.map(
            lambda p_, u: (p_.astype(jnp.float32)
                           + u.astype(jnp.float32)[None]).astype(p_.dtype),
            params_c, delta)

        sq_c = jax.vmap(lambda u: sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(u)))(masked_c)
        energy = jnp.sum((beta / gains[:n_clients]) ** 2 * sq_c)
        metrics = jax.tree.map(jnp.mean, metrics)
        return new_params, dict(metrics, loss=jnp.mean(losses), beta=beta,
                                grad_norm=jnp.mean(gnorms), energy=energy)

    return step


def clientize_shapes(shapes, n_clients: int):
    """Add the leading client dim to a param shape tree."""
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct((n_clients,) + sd.shape, sd.dtype),
        shapes)


def clientize_logical(logical, n_clients: int):
    """Prefix every logical spec with the 'clients' (pod) axis."""
    return jax.tree.map(
        lambda lg: ("clients",) + tuple(lg), logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def clientize_params(params, n_clients: int):
    """Replicate real params along a new client dim (simulation start)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), params)


def make_train_loss_step(cfg: ModelConfig, *, remat: bool = True):
    """Plain (non-FL) train step: loss + grads, for utilities/benchmarks."""
    def step(params, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: T.forward_train(p, cfg, batch, remat=remat),
            has_aux=True)(params)
        return loss, m, g
    return step


def make_prefill_step(cfg: ModelConfig, window: Optional[int] = None):
    def step(params, batch):
        logits, caches, enc_out = T.prefill(params, cfg, batch,
                                            window=window)
        return logits, caches
    return step


def make_serve_step(cfg: ModelConfig, window: Optional[int] = None):
    """ONE new token given a KV cache (decode shapes)."""
    def step(params, token, caches, enc_out=None):
        return T.decode_step(params, cfg, token, caches, window=window,
                             enc_out=enc_out)
    return step
