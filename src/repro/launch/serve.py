"""Batched serving driver: prefill a batch of prompts, then decode tokens.

CPU-scale by default (reduced arch); the full archs are exercised shape-only
by the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
      --reduced --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models import transformer as T


def serve(arch: str, *, reduced: bool, batch: int, prompt_len: int,
          new_tokens: int, seed: int = 0, greedy: bool = True,
          window=None):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    key = jax.random.PRNGKey(seed)
    init_key, tok_key, vis_key, aud_key = jax.random.split(key, 4)
    params, _ = T.init_params(init_key, cfg)

    s_text = prompt_len - cfg.vision_prefix if cfg.family == "vlm" \
        else prompt_len
    toks = jax.random.randint(tok_key, (batch, s_text), 0, cfg.vocab_size)
    pbatch = {"tokens": toks}
    if cfg.family == "vlm":
        pbatch["vision_embeds"] = 0.02 * jax.random.normal(
            vis_key, (batch, cfg.vision_prefix, cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        pbatch["audio_embeds"] = 0.02 * jax.random.normal(
            aud_key, (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(lambda p, b: T.prefill(p, cfg, b,
                                             extra_slots=new_tokens,
                                             window=window))
    decode = jax.jit(lambda p, tok, c, enc: T.decode_step(
        p, cfg, tok, c, window=window, enc_out=enc))

    t0 = time.time()
    logits, caches, enc_out = prefill(params, pbatch)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t1 = time.time()
    for i in range(new_tokens):
        out_tokens.append(tok)
        logits, caches = decode(params, tok, caches, enc_out)
        if greedy:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        else:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(sk, logits[:, -1])[:, None]
        tok = tok.astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    seq = jnp.concatenate(out_tokens, axis=1)
    return {"tokens": seq, "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": batch * new_tokens / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    r = serve(args.arch, reduced=args.reduced, batch=args.batch,
              prompt_len=args.prompt_len, new_tokens=args.new_tokens)
    print(f"prefill {r['prefill_s']:.2f}s decode {r['decode_s']:.2f}s "
          f"({r['tok_per_s']:.1f} tok/s)")
    print("sample tokens:", r["tokens"][0][:16].tolist())


if __name__ == "__main__":
    main()
