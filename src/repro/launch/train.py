"""End-to-end training drivers.

Two modes:
  - ``simulation`` (default): the faithful paper set-up — N simulated edge
    devices, r sampled per round, exact rand_k + AirComp channel, driven
    through the unified ``repro.fl.Trainer`` API (each evaluation chunk is
    one compiled ``lax.scan`` program; the (ε, δ) ledger lives inside the
    compiled ``TrainState``).
  - ``production``: PFELS-as-distributed-optimizer over the mesh (pods =
    clients; DESIGN.md §3), for LLM-scale training on real hardware.

Usage (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.train --mode simulation \
      --algorithm pfels --rounds 100 --epsilon 1.5 --p 0.3
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax

from repro.configs.base import CompressionSchedule, PFELSConfig
from repro.configs.paper_models import BENCH_CNN_CIFAR, BENCH_MLP
from repro.core.channel import scaled_channel
from repro.core.channels import list_channel_models
from repro.core.compressors import list_compressors
from repro.data import make_federated_classification, make_population_source
from repro.fl import Trainer, list_algorithms
from repro.models import cnn


def run_simulation(args):
    model_cfg = BENCH_CNN_CIFAR if args.model == "cnn" else BENCH_MLP
    key = jax.random.PRNGKey(args.seed)
    params = cnn.init_cnn(key, model_cfg)
    d = sum(p.size for p in jax.tree.leaves(params))
    # channel scenario (DESIGN.md §11): the regime-scaled fading floor,
    # specialized to the selected registry model
    chan = dataclasses.replace(
        scaled_channel(d), model=args.channel,
        num_antennas=args.antennas, markov_rho=args.markov_rho,
        dropout_prob=args.dropout_prob)
    cfg = PFELSConfig(
        num_clients=args.clients, clients_per_round=args.sampled,
        local_steps=args.tau, local_lr=args.lr, clip=args.clip,
        compression_ratio=args.p, epsilon=args.epsilon,
        rounds=args.rounds, momentum=args.momentum,
        algorithm=args.algorithm,
        dp_fedavg_sigma=args.dp_sigma,
        bank_backend=args.bank,
        compressor=args.compressor,
        quant_bits=args.quant_bits,
        threshold_frac=args.threshold_frac,
        error_feedback=args.error_feedback,
        transmit_clip=args.transmit_clip,
        schedule=CompressionSchedule(
            mode=args.schedule, k_end_ratio=args.k_end_ratio,
            power_end=args.power_end, eps_floor=args.eps_floor),
        channel=chan)
    image_shape = (model_cfg.in_channels, model_cfg.image_size,
                   model_cfg.image_size)
    if args.bank == "streamed" and args.dirichlet_alpha is None:
        # population-scale path (DESIGN.md §10): on-demand per-client
        # generation + host-side bank; no (n, samples, ...) tensor exists
        x, xt, yt = make_population_source(
            key, n_clients=cfg.num_clients, per_client=args.per_client,
            num_classes=model_cfg.num_classes, image_shape=image_shape)
        y = None
    else:
        x, y, xt, yt = make_federated_classification(
            key, n_clients=cfg.num_clients, per_client=args.per_client,
            num_classes=model_cfg.num_classes, image_shape=image_shape,
            alpha=args.dirichlet_alpha)
    loss_fn = lambda p, b: cnn.cnn_loss(p, model_cfg, b)
    trainer = Trainer(cfg, loss_fn, params)
    state = trainer.init(key)
    history = []
    energy_total = 0.0
    t0 = time.time()
    while int(state.round) < cfg.rounds:
        chunk = min(args.eval_every, cfg.rounds - int(state.round))
        state, m = trainer.run(state, x, y, rounds=chunk)
        energy_total += float(m["energy"].sum())
        tl, acc = trainer.evaluate(state, xt, yt)
        history.append({"round": int(state.round) - 1,
                        "train_loss": float(m["train_loss"][-1]),
                        "test_acc": acc, "energy_cum": energy_total,
                        "subcarriers": int(m["subcarriers"][-1])})
        print(f"[{cfg.algorithm}] round {int(state.round) - 1:4d} loss="
              f"{float(m['train_loss'][-1]):.3f} acc={acc:.3f} "
              f"energy={energy_total:.3e}", flush=True)
    totals = trainer.ledger_totals(state)
    out = {"config": {"algorithm": cfg.algorithm, "epsilon": cfg.epsilon,
                      "p": cfg.compression_ratio, "rounds": cfg.rounds,
                      "clients": cfg.num_clients, "d": d,
                      "channel": cfg.channel.model,
                      "compressor": cfg.compressor,
                      "schedule": cfg.schedule.mode},
           "history": history,
           "energy_total": energy_total,
           "privacy": {"per_round_eps_max": totals["eps_max_round"],
                       "basic_composition": totals["basic"],
                       "advanced_composition": totals["advanced"]},
           "wall_s": time.time() - t0}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="simulation",
                    choices=["simulation"])
    ap.add_argument("--algorithm", default="pfels",
                    choices=list_algorithms())
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--sampled", type=int, default=16)
    ap.add_argument("--per-client", type=int, default=40)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--p", type=float, default=0.3)
    ap.add_argument("--epsilon", type=float, default=1.5)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--dp-sigma", type=float, default=1.0)
    ap.add_argument("--dirichlet-alpha", type=float, default=None)
    ap.add_argument("--channel", default="block_fading",
                    choices=list_channel_models(),
                    help="wireless scenario from the repro.core.channels "
                         "registry (DESIGN.md §11): block_fading is the "
                         "paper's i.i.d. flat fading; markov_fading "
                         "correlates gains across rounds; mimo_mrc gives "
                         "the base station --antennas receive antennas; "
                         "dropout drops each transmission w.p. "
                         "--dropout-prob")
    ap.add_argument("--antennas", type=int, default=4,
                    help="M receive antennas (mimo_mrc)")
    ap.add_argument("--markov-rho", type=float, default=0.9,
                    help="round-to-round gain correlation (markov_fading)")
    ap.add_argument("--dropout-prob", type=float, default=0.1,
                    help="per-round transmission dropout probability")
    ap.add_argument("--compressor", default="rand_k",
                    choices=list_compressors(),
                    help="update compressor from the "
                         "repro.core.compressors registry (DESIGN.md "
                         "§13): rand_k is the paper's sparsifier; "
                         "top_k_ef does magnitude top-k of the released "
                         "aggregate with mandatory error feedback; "
                         "threshold keeps coords above --threshold-frac "
                         "of the max; stoch_quant adds --quant-bits "
                         "unbiased stochastic quantization (its norm "
                         "inflation is charged to the privacy ledger)")
    ap.add_argument("--quant-bits", type=int, default=8,
                    help="signed quantization bits (stoch_quant)")
    ap.add_argument("--threshold-frac", type=float, default=0.1,
                    help="live-coordinate threshold as a fraction of "
                         "max|delta_hat| (threshold)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="per-client error-feedback residual memory "
                         "(forced on by carry compressors like top_k_ef)")
    ap.add_argument("--transmit-clip", type=float, default=None,
                    help="per-client l2 cap on the transmitted update")
    ap.add_argument("--schedule", default="none",
                    choices=["none", "linear", "budget"],
                    help="CompressionSchedule mode (DESIGN.md §13): "
                         "'linear' anneals the live-k fraction to "
                         "--k-end-ratio and power to --power-end over "
                         "the rounds; 'budget' additionally paces the "
                         "per-round epsilon ceiling against the "
                         "remaining eps_total = epsilon * rounds")
    ap.add_argument("--k-end-ratio", type=float, default=1.0,
                    help="final live fraction of the k budget (schedule)")
    ap.add_argument("--power-end", type=float, default=1.0,
                    help="final power-limit multiplier (schedule)")
    ap.add_argument("--eps-floor", type=float, default=0.0,
                    help="per-round epsilon floor (budget schedule)")
    ap.add_argument("--bank", default="resident",
                    choices=["resident", "streamed"],
                    help="ClientBank backend (DESIGN.md §10): 'streamed' "
                         "keeps per-client state host-side and generates "
                         "cohort data on demand — num_clients can be "
                         "100_000+ with device memory independent of n")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_simulation(args)


if __name__ == "__main__":
    main()
