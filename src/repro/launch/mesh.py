"""Production mesh factory. Importing this module never touches jax device
state; call the functions."""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(16,16) 'data','model' single pod (256 chips, v5e) or
    (2,16,16) 'pod','data','model' for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev = np.array(devices[:n]).reshape(shape)
    return Mesh(dev, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Small mesh over however many (host) devices exist — smoke tests."""
    n = int(np.prod(shape))
    dev = np.array(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes, axis_types=(AxisType.Auto,) * len(axes))
