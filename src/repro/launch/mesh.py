"""Production mesh factory. Importing this module never touches jax device
state; call the functions."""
from __future__ import annotations

import enum

import numpy as np

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 has explicit mesh axis types
    from jax.sharding import AxisType
    _HAS_AXIS_TYPES = True
except ImportError:  # jax 0.4.x: every axis is implicitly Auto
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    _HAS_AXIS_TYPES = False


def use_mesh(mesh: Mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on jax >= 0.6,
    the ``with mesh:`` global-mesh context on 0.4.x (where pjit resolves
    unspecified shardings against the thread-local physical mesh)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_mesh(devices: np.ndarray, axes) -> Mesh:
    """Mesh with Auto axis types where the pinned jax supports them, plain
    Mesh otherwise (pre-0.5 Mesh has no ``axis_types`` kwarg and treats all
    axes as Auto anyway)."""
    if _HAS_AXIS_TYPES:
        return Mesh(devices, axes, axis_types=(AxisType.Auto,) * len(axes))
    return Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(16,16) 'data','model' single pod (256 chips, v5e) or
    (2,16,16) 'pod','data','model' for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev = np.array(devices[:n]).reshape(shape)
    return make_mesh(dev, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Small mesh over however many (host) devices exist — smoke tests."""
    n = int(np.prod(shape))
    dev = np.array(jax.devices()[:n]).reshape(shape)
    return make_mesh(dev, axes)
