"""Production mesh factory. Importing this module never touches jax device
state; call the functions."""
from __future__ import annotations

import enum

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5 has explicit mesh axis types
    from jax.sharding import AxisType
    _HAS_AXIS_TYPES = True
except ImportError:  # jax 0.4.x: every axis is implicitly Auto
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    _HAS_AXIS_TYPES = False


def use_mesh(mesh: Mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on jax >= 0.6,
    the ``with mesh:`` global-mesh context on 0.4.x (where pjit resolves
    unspecified shardings against the thread-local physical mesh)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_mesh(devices: np.ndarray, axes) -> Mesh:
    """Mesh with Auto axis types where the pinned jax supports them, plain
    Mesh otherwise (pre-0.5 Mesh has no ``axis_types`` kwarg and treats all
    axes as Auto anyway)."""
    if _HAS_AXIS_TYPES:
        return Mesh(devices, axes, axis_types=(AxisType.Auto,) * len(axes))
    return Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(16,16) 'data','model' single pod (256 chips, v5e) or
    (2,16,16) 'pod','data','model' for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev = np.array(devices[:n]).reshape(shape)
    return make_mesh(dev, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Small mesh over however many (host) devices exist — smoke tests."""
    n = int(np.prod(shape))
    dev = np.array(jax.devices()[:n]).reshape(shape)
    return make_mesh(dev, axes)


def cohort_shape(r: int, n_dev: int):
    """(pod, data) extents for a cohort of r clients on n_dev devices: the
    total is the LARGEST divisor of r that fits, so an awkward r degrades to
    fewer shards — and ultimately to (1, 1), the replicated single-device
    path — instead of failing to lower (the same drop-to-replicated
    convention as ``sharding.rules.resolve_spec``). The shard count is split
    pod-major with pod <= data (pods are the scarcer physical unit)."""
    n = min(max(int(n_dev), 1), max(int(r), 1))
    while n > 1 and r % n:
        n -= 1
    pod = 1
    for p in range(int(n ** 0.5), 0, -1):
        if n % p == 0:
            pod = p
            break
    return pod, n // pod


def make_cohort_mesh(r: int, *, devices=None) -> Mesh:
    """('pod', 'data') mesh for sharded cohort execution (DESIGN.md §7):
    each of the r selected FL clients lives on exactly one mesh slot, so
    the AirComp sum is a physical cross-device psum. Degrades via
    :func:`cohort_shape` when r does not divide the device count."""
    devices = list(jax.devices()) if devices is None else list(devices)
    pod, data = cohort_shape(r, len(devices))
    dev = np.array(devices[: pod * data]).reshape(pod, data)
    return make_mesh(dev, ("pod", "data"))


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (jax.shard_map on new jax,
    jax.experimental.shard_map on the 0.4.x floor), with replication
    checking off — the cohort path communicates via explicit psums."""
    try:
        from jax import shard_map as sm          # jax >= 0.6
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:                             # check_rep -> check_vma
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
