"""HLO cost model with while-loop trip-count multipliers.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body ONCE,
which undercounts scanned layer stacks and flash-attention KV loops by the
trip count. This module parses the compiled (post-SPMD-partitioning) HLO text
and walks the call graph from ENTRY, multiplying each computation's costs by
the product of enclosing loop trip counts (parsed from each loop condition's
comparison constant).

Costs per op:
  flops:  dot = 2 * |result| * prod(contracting dims); convolution =
          2 * |result| * prod(kernel spatial) * C_in/groups; elementwise
          arithmetic = |result| (1 flop/elem; transcendentals counted 1).
          Counted recursively inside fusions.
  bytes:  |result| + sum |operands| for top-level (scheduled) ops; fusions
          count their interface only (operands + result), not their interior
          — the fusion-aware HBM-traffic proxy.
  collective bytes: ring model per kind (see hlo_analysis), multiplied by
          the enclosing trip counts.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u1": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "remainder", "atan2", "expm1", "log1p", "cbrt", "erf",
}
_NO_BYTES = {"tuple", "get-tuple-element", "parameter", "constant",
             "bitcast", "while", "conditional", "after-all", "partition-id",
             "replica-id"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES.get(dt, 4)
    return elems_total, bytes_total


class Op:
    __slots__ = ("name", "shape", "kind", "rest", "operands")

    def __init__(self, name, shape, kind, rest):
        self.name, self.shape, self.kind, self.rest = name, shape, kind, rest
        self.operands: List[str] = []


def _parse_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        h = _COMP_HDR_RE.match(line)
        if h:
            cur = h.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
        # operands: %names inside the first (...) — up to the closing paren
        depth, end = 0, len(m.group(4))
        for i, ch in enumerate(m.group(4)):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        op.operands = _OPERAND_RE.findall(m.group(4)[:end])
        comps[cur].append(op)
    return comps


def _trip_count(comps, cond_name: str) -> int:
    best = 1
    for op in comps.get(cond_name, []):
        for c in _CONST_S32_RE.finditer(op.rest if op.kind == "constant"
                                        else ""):
            pass
    # constants appear as their own ops: `%c = s32[] constant(N)`
    for op in comps.get(cond_name, []):
        if op.kind == "constant" and op.shape.startswith("s32[]"):
            m = re.match(r"(\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return max(best, 1)


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    _, _ = 0, 0
    res_elems, _ = _shape_elems_bytes(op.shape)
    lhs = shapes.get(op.operands[0]) if op.operands else None
    contract = 1
    cm = _CONTRACT_RE.search(op.rest)
    if lhs and cm and cm.group(1):
        dims = [int(x) for x in cm.group(1).split(",")]
        lm = _SHAPE_RE.search(lhs)
        if lm:
            lshape = ([int(x) for x in lm.group(2).split(",")]
                      if lm.group(2) else [])
            for d in dims:
                if d < len(lshape):
                    contract *= lshape[d]
    return 2.0 * res_elems * contract


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(op.shape)
    wm = _WINDOW_RE.search(op.rest)
    spatial = 1
    if wm:
        for s in wm.group(1).split("x"):
            spatial *= int(s)
    cin = 1
    if len(op.operands) > 1:
        k = shapes.get(op.operands[1])
        if k:
            km = _SHAPE_RE.search(k)
            if km and km.group(2):
                kd = [int(x) for x in km.group(2).split(",")]
                # OIHW kernel: dims beyond O are I + spatial; I = prod/spatial/O
                if len(kd) >= 2:
                    cin = kd[1]
    return 2.0 * res_elems * spatial * cin


def _collective_moved(op: Op) -> float:
    _, res_bytes = _shape_elems_bytes(op.shape)
    g = 1
    gm = _GROUPS_RE.search(op.rest)
    if gm:
        g = len(gm.group(1).split(","))
    else:
        gi = _GROUPS_IOTA_RE.search(op.rest)
        if gi:
            g = int(gi.group(2))
    frac = (g - 1) / g if g > 1 else 0.0
    kind = op.kind.replace("-start", "")
    if kind == "all-reduce":
        return 2.0 * frac * res_bytes
    if kind == "all-gather":
        return frac * res_bytes
    if kind == "reduce-scatter":
        return frac * res_bytes * g
    if kind == "all-to-all":
        return frac * res_bytes
    return float(res_bytes)  # collective-permute


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps = _parse_computations(hlo)
    # entry = computation named in `ENTRY` line; _COMP_HDR_RE loses the ENTRY
    # marker, so detect it via the raw text.
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:
        raise ValueError("no ENTRY computation found")

    shapes_per_comp = {
        c: {op.name: op.shape for op in ops} for c, ops in comps.items()
    }

    totals = defaultdict(float)
    visited_stack = set()

    def visit(comp: str, mult: float, top_level: bool):
        if comp not in comps or (comp, mult) in visited_stack:
            pass
        shapes = shapes_per_comp.get(comp, {})
        for op in comps.get(comp, []):
            k = op.kind
            if k == "while":
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                trips = _trip_count(comps, cond.group(1)) if cond else 1
                if body:
                    visit(body.group(1), mult * trips, True)
                if cond:
                    visit(cond.group(1), mult * trips, True)
                continue
            if k == "conditional":
                br = _BRANCHES_RE.search(op.rest)
                if br:
                    for b in _OPERAND_RE.findall(br.group(1)):
                        visit(b, mult, True)
                continue
            if k in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(op.rest)
                if cm:
                    visit(cm.group(1), mult, False)
                # fusion interface bytes
                if top_level:
                    _, rb = _shape_elems_bytes(op.shape)
                    ob = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                             for o in op.operands)
                    totals["bytes"] += mult * (rb + ob)
                continue
            if k in _COLLECTIVES:
                totals["coll"] += mult * _collective_moved(op)
                totals[f"coll_{k.replace('-start','')}"] += \
                    mult * _collective_moved(op)
                _, rb = _shape_elems_bytes(op.shape)
                totals["bytes"] += mult * 2 * rb
                continue
            # flops
            if k == "dot":
                totals["flops"] += mult * _dot_flops(op, shapes)
            elif k == "convolution":
                totals["flops"] += mult * _conv_flops(op, shapes)
            elif k in _ELEMWISE or k in ("reduce", "compare", "select",
                                         "clamp"):
                e, _ = _shape_elems_bytes(op.shape)
                totals["flops"] += mult * e
            # bytes (top-level scheduled ops only)
            if top_level and k not in _NO_BYTES:
                _, rb = _shape_elems_bytes(op.shape)
                ob = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                         for o in op.operands)
                totals["bytes"] += mult * (rb + ob)

    visit(entry, 1.0, True)
    return dict(totals)
