"""Adam (for the server-side adaptive-FL beyond-paper option and the LLM
finetune example)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params):
    z = lambda x: jnp.zeros_like(x, dtype=jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                weight_decay=0.0):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                     * jnp.square(g.astype(jnp.float32)), state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_s = 1.0 / (1 - b1 ** tf)
    vhat_s = 1.0 / (1 - b2 ** tf)

    def upd(p, m, v):
        step = lr * (m * mhat_s) / (jnp.sqrt(v * vhat_s) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    return (jax.tree.map(upd, params, m, v),
            {"m": m, "v": v, "t": t})
