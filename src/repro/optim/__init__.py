from repro.optim.adam import adam_init, adam_update
from repro.optim.schedules import constant, cosine, warmup_cosine
from repro.optim.sgd import sgd_init, sgd_update

__all__ = ["sgd_init", "sgd_update", "adam_init", "adam_update",
           "constant", "cosine", "warmup_cosine"]
