"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        mult = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * frac))
        return jnp.asarray(lr * mult, jnp.float32)
    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        wu = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, wu, cos(step - warmup))
    return f
