"""SGD with momentum (paper §8.1: momentum 0.9)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32),
                        params)


def sgd_update(params, grads, state, *, lr, momentum: float = 0.0):
    """Returns (new_params, new_state)."""
    new_v = jax.tree.map(
        lambda v, g: momentum * v + g.astype(jnp.float32), state, grads)
    new_p = jax.tree.map(
        lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype),
        params, new_v)
    return new_p, new_v
