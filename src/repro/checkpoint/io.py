"""Checkpointing: pytrees -> npz (flattened key paths) + JSON metadata.

``save``/``restore`` handle any pytree; ``save_train_state`` /
``restore_train_state`` are the TrainState-aware layer (DESIGN.md §10):
they carry the ClientBank (EF residuals, per-client PRNG lanes,
participation counts) alongside params/ledger/PRNG key, record the bank
backend + round in the JSON sidecar, and restore each leaf to where its
template leaf lives — device arrays stay device arrays (resident bank),
host numpy stays host numpy (streamed bank), so a checkpoint taken under
one backend resumes under the other.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # npz cannot serialise extension dtypes (bfloat16): widen
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree, meta: Dict[str, Any] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path if path.endswith(".npz") else path + ".npz",
             **_flatten(tree))
    if meta is not None:
        with open(os.path.splitext(path)[0] + ".json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def restore(path: str, like) -> Any:
    """Restore into the structure of `like` (shape/dtype template)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_like = _flatten(like)
    assert set(data.files) == set(flat_like), (
        "checkpoint keys mismatch:",
        set(data.files) ^ set(flat_like))
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_elems, leaf in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_elems)
        restored.append(np.asarray(data[key]).astype(leaf.dtype))
    return jax.tree.unflatten(leaves_paths[1], restored)


def load_meta(path: str) -> Dict[str, Any]:
    with open(os.path.splitext(path)[0] + ".json") as f:
        return json.load(f)


# ---------------------------------------------------- TrainState + bank

def save_train_state(path: str, state, *, backend: str = "resident",
                     extra_meta: Dict[str, Any] = None):
    """Checkpoint a :class:`repro.fl.api.TrainState` (bank included).

    The bank's numpy (streamed) or device (resident) leaves flatten
    identically, so the on-disk layout is backend-independent; ``backend``
    is recorded in the metadata for bookkeeping, not dispatch."""
    meta = {"kind": "train_state", "bank_backend": backend,
            "round": int(state.round),
            "spends": int(state.ledger.spends)}
    if extra_meta:
        meta.update(extra_meta)
    save(path, state, meta=meta)


def restore_train_state(path: str, like):
    """Restore a TrainState into the structure of ``like`` (e.g.
    ``trainer.init(key)``). Each leaf lands where the template leaf
    lives: jax-array templates are ``device_put`` (resident bank),
    numpy templates stay host-side (streamed bank) — which is also how a
    resident checkpoint re-opens as a streamed one and vice versa."""
    restored = restore(path, like)

    def _place(tmpl, leaf):
        if isinstance(tmpl, jax.Array):
            return jax.device_put(leaf)
        return np.asarray(leaf)

    return jax.tree.map(_place, like, restored)
