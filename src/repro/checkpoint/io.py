"""Checkpointing: pytrees -> npz (flattened key paths) + JSON metadata."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # npz cannot serialise extension dtypes (bfloat16): widen
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree, meta: Dict[str, Any] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path if path.endswith(".npz") else path + ".npz",
             **_flatten(tree))
    if meta is not None:
        with open(os.path.splitext(path)[0] + ".json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def restore(path: str, like) -> Any:
    """Restore into the structure of `like` (shape/dtype template)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_like = _flatten(like)
    assert set(data.files) == set(flat_like), (
        "checkpoint keys mismatch:",
        set(data.files) ^ set(flat_like))
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_elems, leaf in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_elems)
        restored.append(np.asarray(data[key]).astype(leaf.dtype))
    return jax.tree.unflatten(leaves_paths[1], restored)


def load_meta(path: str) -> Dict[str, Any]:
    with open(os.path.splitext(path)[0] + ".json") as f:
        return json.load(f)
