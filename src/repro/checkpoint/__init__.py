from repro.checkpoint.io import (load_meta, restore, restore_train_state,
                                 save, save_train_state)

__all__ = ["save", "restore", "load_meta", "save_train_state",
           "restore_train_state"]
