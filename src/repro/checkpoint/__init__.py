from repro.checkpoint.io import load_meta, restore, save

__all__ = ["save", "restore", "load_meta"]
