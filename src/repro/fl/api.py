"""The public FL training API: ``Trainer`` + ``TrainState`` (DESIGN.md §8).

One object owns the compiled round machinery (``Trainer``) and one
registered pytree owns ALL loop state (``TrainState``): params as a pytree
(ravel/unravel is an internal detail), the error-feedback residual memory,
the previous round's reconstructed update ``prev_delta`` (the server_topk
support source — previously smuggled through the metrics dict), the
per-device power limits, the PRNG key, the round counter, and the in-graph
privacy ledger (``repro.core.privacy.LedgerState``), whose (ε, δ)
accumulators are updated INSIDE the compiled program from the realized
per-round β — so ``Trainer.run`` (the ``lax.scan`` path) returns exact
budget totals without T host round-trips, and chunked resume carries the
ledger automatically.

``Trainer.step(state, data_x, data_y) -> (state, metrics)`` and
``Trainer.run(state, data_x, data_y, rounds=T) -> (state, stacked_metrics)``
have one fixed signature and return shape regardless of config — no
``error_feedback`` 3-tuples, no ``delta_hat`` metrics key. Algorithms come
from the ``repro.fl.algorithms`` registry, so new transmit schemes plug in
as entries, not branches.

PRNG contract (also in DESIGN.md §8): ``state.key`` is the key the next
call consumes. ``step`` uses it whole as the round key and advances it by
``fold_in(key, 1)`` — bit-identical to the legacy
``make_round_fn(..., key=state.key)``. ``run(T)`` splits it into T round
keys (``jax.random.split(state.key, T)``) and advances by
``fold_in(key, T)`` — bit-identical to the legacy ``make_training_fn``
scan. The two schedules intentionally match their legacy counterparts, so
``run(T)`` is NOT bitwise T repetitions of ``step`` (both are valid
independent streams).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh

from repro.configs.base import PFELSConfig
from repro.core import privacy
from repro.fl import algorithms, rounds

# init derives the round-key stream by folding this tag into the init key,
# so power-limit sampling and the training stream never share a key
_RUN_STREAM_TAG = 0x5047  # "PG"


@dataclass
class TrainState:
    """All state of the Alg. 2 server loop, as one registered pytree.

    Donate-safe and scan-carry-safe: every field is an array (or params
    pytree), so checkpointing, ``lax.scan``, and chunked resume carry the
    whole loop — including the privacy ledger — with no host-side
    bookkeeping. ``residuals`` is None unless ``cfg.error_feedback``;
    ``prev_delta`` starts at zeros (the documented server_topk cold start).
    """
    params: Any                       # model pytree
    power_limits: jnp.ndarray         # (N,) P_i, fixed per device
    residuals: Optional[jnp.ndarray]  # (N, d) error-feedback memory or None
    prev_delta: jnp.ndarray           # (d,) last reconstructed Delta_hat
    key: jnp.ndarray                  # PRNG key the NEXT step/run consumes
    round: jnp.ndarray                # i32 scalar, rounds completed
    ledger: privacy.LedgerState       # in-graph (eps, delta) accumulators


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=["params", "power_limits", "residuals", "prev_delta",
                 "key", "round", "ledger"],
    meta_fields=[])


class Trainer:
    """Compiled Alg. 2 server loop over a registry algorithm.

    ``Trainer(cfg, loss_fn, params_template, mesh=None)``:

    - ``cfg``: :class:`PFELSConfig`; ``cfg.algorithm`` is resolved through
      ``repro.fl.algorithms.get_algorithm``.
    - ``loss_fn(params, {"x","y"}) -> (loss, aux)``.
    - ``params_template``: a concrete params pytree — defines the flat
      dimension ``d`` and the unravel mapping internally, and is the
      default initial params for :meth:`init`.
    - ``mesh``: cohort mesh for ``cfg.client_sharding="cohort"``
      (``None`` builds ``make_cohort_mesh(cfg.clients_per_round)``).

    ``step`` is a jitted callable attribute (so ``trainer.step.lower(...)``
    works for dry-runs); ``run`` jits one program per distinct T.
    """

    def __init__(self, cfg: PFELSConfig, loss_fn: Callable,
                 params_template: Any, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.algorithm = algorithms.get_algorithm(cfg.algorithm)
        flat, unravel = ravel_pytree(params_template)
        self.d = int(flat.shape[0])
        self.unravel = unravel
        self._params_template = params_template
        self.mesh = rounds._resolve_cohort_mesh(cfg, mesh)
        self._core = rounds._build_round_core(cfg, loss_fn, self.d, unravel,
                                              self.mesh)
        self.step = jax.jit(self._step_impl)
        self._run_cache: Dict[int, Callable] = {}

    # ------------------------------------------------------------- state

    def init(self, key, params: Any = None) -> TrainState:
        """Fresh TrainState: power limits drawn from ``key`` (the same draw
        as the legacy ``setup``), zeroed ledger/residuals/prev_delta, and
        the round-key stream forked off ``key`` (never reusing the
        power-limit draw)."""
        params = self._params_template if params is None else params
        res = (jnp.zeros((self.cfg.num_clients, self.d), jnp.float32)
               if self.cfg.error_feedback else None)
        return TrainState(
            params=params,
            power_limits=rounds.init_power_limits(key, self.cfg, self.d),
            residuals=res,
            prev_delta=jnp.zeros((self.d,), jnp.float32),
            key=jax.random.fold_in(key, _RUN_STREAM_TAG),
            round=jnp.zeros((), jnp.int32),
            ledger=privacy.ledger_init())

    def _advance(self, state: TrainState, n: int, params, residuals,
                 prev_delta, ledger) -> TrainState:
        return TrainState(
            params=params, power_limits=state.power_limits,
            residuals=residuals, prev_delta=prev_delta,
            key=jax.random.fold_in(state.key, n),
            round=state.round + n, ledger=ledger)

    def _spend(self, ledger, metrics):
        """Ledger update + the uniform ``eps_round`` metric. Whether the
        algorithm spends budget is static config, so non-DP schemes carry
        the ledger through untouched (their totals stay (0.0, 0.0) — the
        empty-ledger contract)."""
        if self.algorithm.privacy_spend is None:
            eps_round = jnp.zeros((), jnp.float32)
        else:
            eps_round = jnp.asarray(
                self.algorithm.privacy_spend(self.cfg, metrics["beta"]),
                jnp.float32)
            ledger = privacy.ledger_spend(ledger, eps_round)
        return ledger, dict(metrics, eps_round=eps_round)

    # ------------------------------------------------------------- loops

    def _step_impl(self, state: TrainState, data_x, data_y):
        new_params, metrics, new_res, delta_hat = self._core(
            state.params, state.power_limits, data_x, data_y, state.key,
            state.residuals, state.prev_delta)
        ledger, metrics = self._spend(state.ledger, metrics)
        return self._advance(state, 1, new_params, new_res, delta_hat,
                             ledger), metrics

    def run(self, state: TrainState, data_x, data_y,
            rounds: Optional[int] = None):
        """T rounds as ONE ``lax.scan`` program (T defaults to
        ``cfg.rounds``). Returns ``(state, metrics)`` with every metrics
        leaf stacked over the T rounds (leading axis T). Chunked resume is
        just calling ``run`` again with the returned state — residuals,
        server_topk support, PRNG stream, and the privacy ledger all carry
        in ``TrainState``."""
        t = self.cfg.rounds if rounds is None else int(rounds)
        fn = self._run_cache.get(t)
        if fn is None:
            fn = jax.jit(lambda s, x, y: self._run_impl(s, x, y, t))
            self._run_cache[t] = fn
        return fn(state, data_x, data_y)

    def _run_impl(self, state: TrainState, data_x, data_y, t_rounds: int):
        def body(carry, round_key):
            p, res, prev, ledger = carry
            p2, metrics, res2, delta_hat = self._core(
                p, state.power_limits, data_x, data_y, round_key, res, prev)
            ledger, metrics = self._spend(ledger, metrics)
            return (p2, res2, delta_hat, ledger), metrics

        keys = jax.random.split(state.key, t_rounds)
        (p_f, res_f, delta_f, ledger_f), metrics = jax.lax.scan(
            body, (state.params, state.residuals, state.prev_delta,
                   state.ledger), keys)
        return self._advance(state, t_rounds, p_f, res_f, delta_f,
                             ledger_f), metrics

    # ------------------------------------------------------- conveniences

    def ledger_totals(self, state: TrainState,
                      delta_prime: float = 1e-6) -> Dict[str, Any]:
        """Host-side (eps_T, delta_T) report from the in-graph ledger,
        matching the legacy ``PrivacyLedger`` contract."""
        delta = self.cfg.resolved_delta()
        return {
            "basic": privacy.ledger_totals_basic(state.ledger, delta),
            "advanced": privacy.ledger_totals_advanced(state.ledger, delta,
                                                       delta_prime),
            "eps_max_round": float(state.ledger.eps_max),
            "spends": int(state.ledger.spends),
        }

    def evaluate(self, state: TrainState, xt, yt, batch: int = 256):
        """(test_loss, test_accuracy) of ``state.params`` — thin wrapper
        over :func:`repro.fl.rounds.evaluate`."""
        return rounds.evaluate(state.params, self.loss_fn, xt, yt,
                               batch=batch)


def replace(state: TrainState, **kw) -> TrainState:
    """``dataclasses.replace`` re-export for ergonomic state surgery
    (tests pin ``key=``; checkpoint restore swaps ``params=``)."""
    return dataclasses.replace(state, **kw)
