"""The public FL training API: ``Trainer`` + ``TrainState`` (DESIGN.md §8).

One object owns the compiled round machinery (``Trainer``) and one
registered pytree owns ALL loop state (``TrainState``): params as a pytree
(ravel/unravel is an internal detail), the per-client ``ClientBank``
(error-feedback residuals, PRNG lanes, participation counts —
``repro.fl.bank``, DESIGN.md §10), the previous round's reconstructed
update ``prev_delta`` (the server_topk support source — previously
smuggled through the metrics dict), the per-device power limits, the PRNG
key, the round counter, and the in-graph privacy ledger
(``repro.core.privacy.LedgerState``), whose (ε, δ) accumulators are
updated INSIDE the compiled program from the realized per-round β — so
``Trainer.run`` (the ``lax.scan`` path) returns exact budget totals
without T host round-trips, and chunked resume carries the ledger
automatically.

``cfg.bank_backend`` selects where the bank lives: ``resident`` (dense
device arrays in the scan carry — the bit-exact reference) or
``streamed`` (host-side bank + double-buffer-prefetched cohort slices;
device memory independent of ``num_clients``). The two are bit-identical
under the same key; ``run``/``step`` signatures do not change.

``Trainer.step(state, data_x, data_y) -> (state, metrics)`` and
``Trainer.run(state, data_x, data_y, rounds=T) -> (state, stacked_metrics)``
have one fixed signature and return shape regardless of config — no
``error_feedback`` 3-tuples, no ``delta_hat`` metrics key. Algorithms come
from the ``repro.fl.algorithms`` registry, so new transmit schemes plug in
as entries, not branches.

PRNG contract (also in DESIGN.md §8): ``state.key`` is the key the next
call consumes. ``step`` uses it whole as the round key and advances it by
``fold_in(key, 1)`` — bit-identical to the legacy
``make_round_fn(..., key=state.key)``. ``run(T)`` splits it into T round
keys (``jax.random.split(state.key, T)``) and advances by
``fold_in(key, T)`` — bit-identical to the legacy ``make_training_fn``
scan. The two schedules intentionally match their legacy counterparts, so
``run(T)`` is NOT bitwise T repetitions of ``step`` (both are valid
independent streams).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh

from repro.configs.base import PFELSConfig
from repro.core import channels, compressors, privacy
from repro.data import loader
from repro.fl import algorithms, rounds
from repro.fl import bank as bank_lib

# init derives the round-key stream by folding this tag into the init key,
# so power-limit sampling and the training stream never share a key
_RUN_STREAM_TAG = 0x5047  # "PG"
# ...and the channel model's init state gets its own fork for the same
# reason (markov_fading's stationary start must not alias either stream)
_CHAN_STREAM_TAG = 0x4348  # "CH"


@dataclass
class TrainState:
    """All state of the Alg. 2 server loop, as one registered pytree.

    Donate-safe and scan-carry-safe: every field is an array (or params
    pytree), so checkpointing, ``lax.scan``, and chunked resume carry the
    whole loop — including the privacy ledger and the per-client
    ``ClientBank`` state — with no host-side bookkeeping. ``bank`` holds
    ALL per-client persistent state (error-feedback residuals, PRNG
    lanes, participation counts; DESIGN.md §10) — device arrays under the
    ``resident`` backend, host numpy under ``streamed``. ``prev_delta``
    starts at zeros (the documented server_topk cold start). ``chan`` is
    the channel model's cross-round carry (DESIGN.md §11) — ``None`` for
    stateless models (block_fading, mimo_mrc), the population's latent
    fading state for markov_fading — always device-resident, under both
    bank backends.
    """
    params: Any                       # model pytree
    power_limits: jnp.ndarray         # (N,) P_i, fixed per device
    bank: bank_lib.BankState          # per-client state (DESIGN.md §10)
    prev_delta: jnp.ndarray           # (d,) last reconstructed Delta_hat
    key: jnp.ndarray                  # PRNG key the NEXT step/run consumes
    round: jnp.ndarray                # i32 scalar, rounds completed
    ledger: privacy.LedgerState       # in-graph (eps, delta) accumulators
    chan: Any = None                  # channel-model carry (DESIGN.md §11;
    #                                   None for stateless models)

    @property
    def residuals(self) -> Optional[jnp.ndarray]:
        """(N, d) error-feedback memory (None unless
        ``cfg.error_feedback``) — lives in the bank; kept as a read alias
        for the pre-bank field."""
        return self.bank.residuals


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=["params", "power_limits", "bank", "prev_delta",
                 "key", "round", "ledger", "chan"],
    meta_fields=[])


class Trainer:
    """Compiled Alg. 2 server loop over a registry algorithm.

    ``Trainer(cfg, loss_fn, params_template, mesh=None)``:

    - ``cfg``: :class:`PFELSConfig`; ``cfg.algorithm`` is resolved through
      ``repro.fl.algorithms.get_algorithm``.
    - ``loss_fn(params, {"x","y"}) -> (loss, aux)``.
    - ``params_template``: a concrete params pytree — defines the flat
      dimension ``d`` and the unravel mapping internally, and is the
      default initial params for :meth:`init`.
    - ``mesh``: cohort mesh for ``cfg.client_sharding="cohort"``
      (``None`` builds ``make_cohort_mesh(cfg.clients_per_round)``).

    ``step`` is a jitted callable attribute (so ``trainer.step.lower(...)``
    works for dry-runs); ``run`` jits one program per distinct T.
    """

    def __init__(self, cfg: PFELSConfig, loss_fn: Callable,
                 params_template: Any, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.algorithm = algorithms.get_algorithm(cfg.algorithm)
        self.channel_model = channels.get_channel_model(cfg.channel.model)
        flat, unravel = ravel_pytree(params_template)
        self.d = int(flat.shape[0])
        self.unravel = unravel
        self._params_template = params_template
        self.mesh = rounds._resolve_cohort_mesh(cfg, mesh)
        # carry-compressors (top_k_ef) force the bank's error-feedback
        # residual memory on even with cfg.error_feedback=False
        # (DESIGN.md §13) — mirrors the round body's ``ef_on`` static
        ef_on = cfg.error_feedback or (
            self.algorithm.aircomp and self.algorithm.sparsifies_transmit
            and compressors.carry_required(cfg))
        self.bank = bank_lib.make_bank(cfg.bank_backend, cfg.num_clients,
                                       self.d, ef_on)
        if self.bank.backend == "streamed" and self.mesh is not None:
            raise ValueError(
                "bank_backend='streamed' is host-driven and does not "
                "compose with client_sharding='cohort' yet — stream the "
                "bank OR shard the cohort (DESIGN.md §10)")
        self._cohort_core = rounds._build_cohort_core(
            cfg, loss_fn, self.d, unravel, self.mesh)
        self._core = rounds._build_round_core(
            cfg, loss_fn, self.d, unravel, self.mesh,
            cohort_core=self._cohort_core)
        self.step = (self._streamed_step_api
                     if self.bank.backend == "streamed"
                     else jax.jit(self._step_impl))
        self._run_cache: Dict[int, Callable] = {}
        self._cohort_step_jit: Optional[Callable] = None

    # ------------------------------------------------------------- state

    def init(self, key, params: Any = None) -> TrainState:
        """Fresh TrainState: power limits drawn from ``key`` (the same draw
        as the legacy ``setup``), zeroed ledger/bank/prev_delta, the
        channel model's cross-round state initialized from its own fork of
        ``key`` (None for stateless models), and the round-key stream
        forked off ``key`` (never reusing the power-limit draw)."""
        params = self._params_template if params is None else params
        return TrainState(
            params=params,
            power_limits=rounds.init_power_limits(key, self.cfg, self.d),
            bank=self.bank.init(),
            prev_delta=jnp.zeros((self.d,), jnp.float32),
            key=jax.random.fold_in(key, _RUN_STREAM_TAG),
            round=jnp.zeros((), jnp.int32),
            ledger=privacy.ledger_init(),
            chan=self.channel_model.init(
                jax.random.fold_in(key, _CHAN_STREAM_TAG),
                self.cfg.num_clients, self.cfg.channel))

    def _advance(self, state: TrainState, n: int, params, bank,
                 prev_delta, ledger, chan) -> TrainState:
        return TrainState(
            params=params, power_limits=state.power_limits,
            bank=bank, prev_delta=prev_delta,
            key=jax.random.fold_in(state.key, n),
            round=state.round + n, ledger=ledger, chan=chan)

    def _spend(self, ledger, metrics):
        """Ledger update + the uniform ``eps_round`` metric. Whether the
        algorithm spends budget is static config, so non-DP schemes carry
        the ledger through untouched (their totals stay (0.0, 0.0) — the
        empty-ledger contract)."""
        if self.algorithm.privacy_spend is None:
            eps_round = jnp.zeros((), jnp.float32)
        else:
            eps_round = jnp.asarray(
                self.algorithm.privacy_spend(self.cfg, metrics["beta"],
                                             self.d),
                jnp.float32)
            ledger = privacy.ledger_spend(ledger, eps_round)
        return ledger, dict(metrics, eps_round=eps_round)

    # ------------------------------------------------------------- loops

    def _bank_round(self, params, power_limits, bank, prev_delta, chan,
                    data_x, data_y, round_key, t=None, eps_spent=None):
        """One round against the in-graph (resident) bank: sample the
        cohort, gather its slices, run the cohort core (which also evolves
        the channel-model carry, DESIGN.md §11), scatter the residual
        slice + this round's bank lanes back (DESIGN.md §10). ``t`` (the
        absolute round counter) and ``eps_spent`` (the ledger's running
        sum) feed the CompressionSchedule inside the compiled body
        (DESIGN.md §13) — traced scalars, never a host round-trip."""
        ks = rounds.split_round_key(round_key)
        sel = rounds.sample_cohort(ks[rounds.ROUND_KEY_LANES["selection"]],
                                   self.cfg.num_clients,
                                   self.cfg.clients_per_round)
        res_sel = self.bank.gather(bank, sel)
        new_params, metrics, new_res_sel, delta_hat, new_chan = \
            self._cohort_core(
                params, power_limits[sel], data_x[sel], data_y[sel], ks,
                res_sel, prev_delta, chan, sel, t, eps_spent)
        lanes = bank_lib.cohort_lane_keys(
            ks[rounds.ROUND_KEY_LANES["bank"]], sel)
        new_bank = self.bank.scatter(bank, sel, new_res_sel, lanes)
        return new_params, metrics, new_bank, delta_hat, new_chan

    def _step_impl(self, state: TrainState, data_x, data_y):
        new_params, metrics, new_bank, delta_hat, new_chan = \
            self._bank_round(
                state.params, state.power_limits, state.bank,
                state.prev_delta, state.chan, data_x, data_y, state.key,
                state.round, state.ledger.eps_sum)
        ledger, metrics = self._spend(state.ledger, metrics)
        return self._advance(state, 1, new_params, new_bank, delta_hat,
                             ledger, new_chan), metrics

    def run(self, state: TrainState, data_x, data_y=None,
            rounds: Optional[int] = None):
        """T rounds (T defaults to ``cfg.rounds``). Returns
        ``(state, metrics)`` with every metrics leaf stacked over the T
        rounds (leading axis T). Chunked resume is just calling ``run``
        again with the returned state — the bank (EF residuals, lanes,
        counts), server_topk support, PRNG stream, and the privacy ledger
        all carry in ``TrainState``.

        Under the ``resident`` bank this is ONE ``lax.scan`` program over
        device-resident population tensors. Under ``streamed`` it is the
        host-driven cohort loop (DESIGN.md §10): ``data_x``/``data_y`` may
        be host arrays or a :class:`repro.data.loader.CohortSource`, the
        per-round cohorts are double-buffer prefetched, and only
        ``(r, ...)`` slices ever reach the device — both backends are
        bit-identical under the same key."""
        t = self.cfg.rounds if rounds is None else int(rounds)
        if self.bank.backend == "streamed":
            return self._run_streamed(state, data_x, data_y, t)
        fn = self._run_cache.get(t)
        if fn is None:
            fn = jax.jit(lambda s, x, y: self._run_impl(s, x, y, t))
            self._run_cache[t] = fn
        return fn(state, data_x, data_y)

    def _run_impl(self, state: TrainState, data_x, data_y, t_rounds: int):
        def body(carry, xs):
            round_key, t = xs
            p, bank, prev, ledger, chan = carry
            p2, metrics, bank2, delta_hat, chan2 = self._bank_round(
                p, state.power_limits, bank, prev, chan, data_x, data_y,
                round_key, t, ledger.eps_sum)
            ledger, metrics = self._spend(ledger, metrics)
            return (p2, bank2, delta_hat, ledger, chan2), metrics

        keys = jax.random.split(state.key, t_rounds)
        # absolute round counters, so chunked resume anneals the
        # CompressionSchedule from where the last chunk stopped
        ts = state.round + jnp.arange(t_rounds, dtype=jnp.int32)
        (p_f, bank_f, delta_f, ledger_f, chan_f), metrics = jax.lax.scan(
            body, (state.params, state.bank, state.prev_delta,
                   state.ledger, state.chan), (keys, ts))
        return self._advance(state, t_rounds, p_f, bank_f, delta_f,
                             ledger_f, chan_f), metrics

    # ------------------------------------------------- streamed execution

    def _cohort_step(self):
        """The jitted streamed round: pure cohort slices in, cohort slices
        out. The ``res_sel`` gather buffer is donated — XLA reuses it for
        the ``new_res_sel`` output, so the (r, d) scatter staging buffer
        is recycled across rounds instead of accumulating (DESIGN.md §10).
        ``cx``/``cy`` are not donated: no output shares their shape, so
        donation could never be honored."""
        if self._cohort_step_jit is None:
            # ``t`` rides at the END so res_sel keeps position 6 for the
            # donate_argnums contract; the schedule's eps_spent comes from
            # the ledger argument INSIDE the jitted step (same traced
            # value the resident scan reads from its carry)
            def step_fn(params, p_sel, cx, cy, ks, sel, res_sel,
                        prev_delta, ledger, chan, t):
                new_params, metrics, new_res_sel, delta_hat, new_chan = \
                    self._cohort_core(params, p_sel, cx, cy, ks, res_sel,
                                      prev_delta, chan, sel, t,
                                      ledger.eps_sum)
                ledger, metrics = self._spend(ledger, metrics)
                lanes = bank_lib.cohort_lane_keys(
                    ks[rounds.ROUND_KEY_LANES["bank"]], sel)
                return (new_params, metrics, new_res_sel, lanes, delta_hat,
                        ledger, new_chan)

            self._cohort_step_jit = jax.jit(step_fn, donate_argnums=(6,))
        return self._cohort_step_jit

    def _streamed_rounds(self, state: TrainState, source, round_keys):
        """Drive ``len(round_keys)`` rounds with the bank host-side: only
        the sampled cohort's data/residual slices move on/off device.

        Clones the host bank ONCE per call (callers keep their states
        valid), so the O(n·d) memcpy amortizes over the rounds of a
        ``run`` — prefer ``run(rounds=T)`` over a ``step`` loop with the
        streamed backend."""
        cfg = self.cfg
        n, r = cfg.num_clients, cfg.clients_per_round
        if getattr(source, "n", n) != n:
            raise ValueError(
                f"cohort source serves {source.n} clients but "
                f"cfg.num_clients={n}: Alg. 2 line 2 samples from "
                f"cfg.num_clients, so a mismatched source silently "
                f"truncates the population (and the Thm 2 r/n "
                f"accounting)")
        ks_all = jax.vmap(rounds.split_round_key)(round_keys)  # (T, 7, ·)
        sels = jax.vmap(lambda ks: rounds.sample_cohort(
            ks[rounds.ROUND_KEY_LANES["selection"]], n, r))(ks_all)
        sels_np = np.asarray(sels)
        step_fn = self._cohort_step()

        bank = self.bank.clone(state.bank)   # callers keep their state
        params, prev_delta, ledger = state.params, state.prev_delta, \
            state.ledger
        chan = state.chan                    # device-resident model carry
        per_round = []
        prefetch = loader.prefetch_cohorts(source, sels_np)
        for ti, (cx, cy) in enumerate(prefetch):
            sel = sels_np[ti]
            res_sel = self.bank.gather(bank, sel)
            if res_sel is not None:
                res_sel = jnp.asarray(res_sel)
            params, metrics, new_res_sel, lanes, prev_delta, ledger, \
                chan = step_fn(
                    params, jnp.asarray(state.power_limits)[sel],
                    cx, cy, ks_all[ti], jnp.asarray(sel), res_sel,
                    prev_delta, ledger, chan,
                    state.round + jnp.asarray(ti, jnp.int32))
            bank = self.bank.scatter(bank, sel, new_res_sel, lanes)
            per_round.append(metrics)
        stacked = {k: np.stack([np.asarray(m[k]) for m in per_round])
                   for k in per_round[0]}
        return params, stacked, bank, prev_delta, ledger, chan

    def _run_streamed(self, state: TrainState, data_x, data_y, t: int):
        if t < 1:
            raise ValueError(
                "run(rounds=0) is not meaningful with the streamed bank "
                "(the metric structure comes from executed rounds); call "
                "with rounds >= 1")
        source = loader.as_cohort_source(data_x, data_y)
        keys = jax.random.split(state.key, t)
        params, metrics, bank, prev_delta, ledger, chan = \
            self._streamed_rounds(state, source, keys)
        return self._advance(state, t, params, bank, prev_delta,
                             ledger, chan), metrics

    def _streamed_step_api(self, state: TrainState, data_x, data_y=None):
        """Streamed ``step``: consumes ``state.key`` whole as the round
        key (the resident/legacy schedule), not ``split(key, 1)``."""
        source = loader.as_cohort_source(data_x, data_y)
        params, metrics, bank, prev_delta, ledger, chan = \
            self._streamed_rounds(state, source, state.key[None])
        metrics = {k: v[0] for k, v in metrics.items()}
        return self._advance(state, 1, params, bank, prev_delta,
                             ledger, chan), metrics

    # ------------------------------------------------------- conveniences

    def ledger_totals(self, state: TrainState,
                      delta_prime: float = 1e-6) -> Dict[str, Any]:
        """Host-side (eps_T, delta_T) report from the in-graph ledger,
        matching the legacy ``PrivacyLedger`` contract."""
        delta = self.cfg.resolved_delta()
        return {
            "basic": privacy.ledger_totals_basic(state.ledger, delta),
            "advanced": privacy.ledger_totals_advanced(state.ledger, delta,
                                                       delta_prime),
            "eps_max_round": float(state.ledger.eps_max),
            "spends": int(state.ledger.spends),
        }

    def evaluate(self, state: TrainState, xt, yt, batch: int = 256):
        """(test_loss, test_accuracy) of ``state.params`` — thin wrapper
        over :func:`repro.fl.rounds.evaluate`."""
        return rounds.evaluate(state.params, self.loss_fn, xt, yt,
                               batch=batch)


def replace(state: TrainState, **kw) -> TrainState:
    """``dataclasses.replace`` re-export for ergonomic state surgery
    (tests pin ``key=``; checkpoint restore swaps ``params=``)."""
    return dataclasses.replace(state, **kw)
