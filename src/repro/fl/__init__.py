from repro.core.privacy import LedgerState
from repro.fl.algorithms import (Algorithm, get_algorithm, list_algorithms,
                                 register_algorithm, unregister_algorithm)
from repro.fl.api import Trainer, TrainState
from repro.fl.bank import (BankState, ClientBank, ResidentBank,
                           StreamedBank, make_bank)
from repro.fl.client import local_train, model_update
from repro.fl.rounds import (FLState, evaluate, make_round_fn,
                             make_training_fn, round_epsilon_spent,
                             sample_cohort, setup, split_round_key)

__all__ = ["Algorithm", "BankState", "ClientBank", "LedgerState",
           "ResidentBank", "StreamedBank", "Trainer", "TrainState",
           "get_algorithm", "list_algorithms", "make_bank",
           "register_algorithm", "unregister_algorithm", "local_train",
           "model_update", "FLState", "evaluate", "make_round_fn",
           "make_training_fn", "round_epsilon_spent", "sample_cohort",
           "setup", "split_round_key"]
