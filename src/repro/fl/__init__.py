from repro.core.privacy import LedgerState
from repro.fl.algorithms import (Algorithm, get_algorithm, list_algorithms,
                                 register_algorithm, unregister_algorithm)
from repro.fl.api import Trainer, TrainState
from repro.fl.client import local_train, model_update
from repro.fl.rounds import (FLState, evaluate, make_round_fn,
                             make_training_fn, round_epsilon_spent, setup)

__all__ = ["Algorithm", "LedgerState", "Trainer", "TrainState",
           "get_algorithm", "list_algorithms", "register_algorithm",
           "unregister_algorithm", "local_train", "model_update", "FLState",
           "evaluate", "make_round_fn", "make_training_fn",
           "round_epsilon_spent", "setup"]
