from repro.fl.client import local_train, model_update
from repro.fl.rounds import (FLState, evaluate, make_round_fn,
                             make_training_fn, round_epsilon_spent, setup)

__all__ = ["local_train", "model_update", "FLState", "evaluate",
           "make_round_fn", "make_training_fn", "round_epsilon_spent",
           "setup"]
