"""The full PFELS round (Alg. 2) and baselines, simulation mode.

One jitted ``round_fn`` runs: sample r clients -> vmapped local training ->
rand_k projection -> Theorem-5 power control -> AirComp over the simulated
MAC -> server update. Baselines (WFL-P Eq. 36, WFL-PDP Eq. 37, DP-FedAvg
Alg. 1, FedAvg) share the same structure with their own aggregation.

Sharded cohort execution (``cfg.client_sharding="cohort"``, DESIGN.md §7):
the per-client pipeline (local training -> error-feedback add -> clip ->
rand-k -> power scaling) runs under ``shard_map`` with the r selected
clients partitioned over the ("pod", "data") mesh axes, and the AirComp
sum becomes a physical cross-device ``psum`` — the over-the-air
superposition as a distributed reduction. Three invariants keep it
numerically aligned with the vmapped single-device path:

  1. every PRNG draw (client sampling, per-client train keys, gains, rand-k
     support, channel noise) happens from the SAME keys as the vmapped
     path, outside the manual region or replicated inside it;
  2. the per-client flat updates come back sharded over the cohort axis, so
     the error-feedback scatter-back ``residuals.at[sel].set`` and all
     metrics reuse the single-device code unchanged;
  3. the Theorem-5 ``beta`` is computed from the globally sampled gains
     before entering the manual region (it is a min over all r clients).
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import PFELSConfig
from repro.core import aggregation, channel, channels, compressors, privacy
from repro.fl import algorithms
from repro.fl.client import local_train, model_update
from repro.kernels.pfels_transmit import ref as transmit_ref
from repro.launch.mesh import make_cohort_mesh, shard_map_compat
from repro.sharding import rules

_COHORT_AXES = ("pod", "data")

# The fixed 7-lane split of each round key (DESIGN.md §5). Which lane
# feeds which draw is a compatibility contract — pinned by
# tests/test_bank.py::test_key_lane_contract — because silently shifting
# a lane re-randomizes every stream in the round.
ROUND_KEY_LANES = {
    "selection": 0,      # Alg. 2 line 2 client sampling
    "client_train": 1,   # per-client local-training keys
    "gains": 2,          # channel-model step: gains (+ fold_in-derived
                         # draws such as the dropout mask, DESIGN.md §11)
    "support": 3,        # rand-k support omega_t
    "channel_noise": 4,  # receiver noise (or digital-aggregation noise)
    "bank": 5,           # ClientBank per-client lanes (DESIGN.md §10)
    "csi": 6,            # CSI estimation error (beyond paper)
}


def split_round_key(key):
    """The per-round 7-subkey split (DESIGN.md §5) — every execution path
    (legacy shims, Trainer resident scan, Trainer streamed loop) consumes
    lanes from this one split."""
    return jax.random.split(key, len(ROUND_KEY_LANES))


def sample_cohort(key, n: int, r: int):
    """Alg. 2 line 2: sample r of n clients without replacement. ``key``
    must be the round's ``selection`` lane."""
    return jax.random.choice(key, n, (r,), replace=False)


@dataclass
class FLState:
    params: Any
    power_limits: jnp.ndarray       # (N,) P_i, fixed per device
    residuals: Optional[Any] = None  # (N, d) error-feedback memory [28-30]
    round: int = 0


def setup(key, params, cfg: PFELSConfig, d: int) -> FLState:
    """DEPRECATED legacy state factory — prefer
    ``repro.fl.Trainer(cfg, loss_fn, params).init(key)``, which returns a
    :class:`repro.fl.api.TrainState` owning ALL loop state (params,
    residuals, prev_delta, PRNG key, in-graph privacy ledger). This shim
    draws the same power limits from the same key and survives only for the
    golden-parity tests."""
    warnings.warn(
        "repro.fl.setup is deprecated; use repro.fl.Trainer(...).init(key) "
        "(DESIGN.md §8)", DeprecationWarning, stacklevel=2)
    p_lim = init_power_limits(key, cfg, d)
    res = (jnp.zeros((cfg.num_clients, d), jnp.float32)
           if cfg.error_feedback else None)
    return FLState(params=params, power_limits=p_lim, residuals=res)


def init_power_limits(key, cfg: PFELSConfig, d: int) -> jnp.ndarray:
    """(N,) per-device power limits P_i — the one draw shared by the legacy
    ``setup`` and ``Trainer.init`` (same key => same limits)."""
    return channel.sample_power_limits(key, cfg.num_clients, d, cfg.channel)


def _resolve_cohort_mesh(cfg: PFELSConfig,
                         mesh: Optional[Mesh]) -> Optional[Mesh]:
    """The mesh the cohort will shard over, or None for the vmapped path.
    With ``client_sharding="cohort"`` and no explicit mesh, builds one over
    the visible devices sized to divide ``clients_per_round``."""
    if cfg.client_sharding == "none":
        return None
    if cfg.client_sharding != "cohort":
        raise ValueError(
            f"unknown client_sharding mode {cfg.client_sharding!r}")
    return mesh if mesh is not None else make_cohort_mesh(
        cfg.clients_per_round)


def _cohort_shards(cfg: PFELSConfig, mesh: Optional[Mesh]) -> int:
    """Static shard count the round will actually use: the ('pod','data')
    extent of `mesh` when it divides r, else 1 — the drop-to-replicated
    convention of ``sharding.rules.resolve_spec`` applied to the client
    dim."""
    if mesh is None or cfg.client_sharding == "none":
        return 1
    n = rules.cohort_axis_size(mesh)
    if n <= 1 or cfg.clients_per_round % n != 0:
        return 1
    return n


def _build_cohort_core(cfg: PFELSConfig, loss_fn: Callable, d: int,
                       unravel: Callable, mesh: Optional[Mesh] = None):
    """The raw (un-jitted) round body on COHORT slices, uniform across
    algorithms AND channel models: ``cohort_core(params, p_sel, cx, cy,
    ks, res_sel, prev_delta, chan_carry, sel) -> (new_params, metrics,
    new_res_sel, delta_hat, new_chan_carry)`` where every client-indexed
    input/output is the sampled r-client slice — ``p_sel`` (r,),
    ``cx``/``cy`` (r, samples, ...), ``res_sel`` (r, d) or None — and
    ``ks`` is the ``split_round_key`` output (lanes 1-6 consumed here;
    selection/bank lanes 0 and 5 belong to the caller).

    The wireless scenario resolves through the ``repro.core.channels``
    registry (DESIGN.md §11): ``chan_carry`` is the model's cross-round
    state pytree (None for stateless models) and ``sel`` the sampled
    client ids (stateful models index their per-client state by id).
    The model's ``step`` consumes the gains/csi lanes, its post-combining
    ``noise_std`` replaces the raw sigma_0 everywhere (receiver draw, β
    privacy cap via the registry hooks, ledger spend), and its optional
    transmit mask routes the realized-r aggregation paths.

    Population tensors never enter: this is what lets the streamed
    ClientBank (DESIGN.md §10) run the identical compiled body on
    host-gathered cohorts with device memory independent of n. With
    ``cfg.client_sharding="cohort"`` and a multi-device `mesh`, the
    per-client pipeline is shard_mapped over the cohort axis (module
    docstring)."""
    k_coords = max(int(round(cfg.compression_ratio * d)), 1)
    alg = algorithms.get_algorithm(cfg.algorithm)
    chan_model = channels.get_channel_model(cfg.channel.model)
    sigma0 = chan_model.noise_std(cfg.channel)
    has_mask = chan_model.may_mask(cfg.channel)
    r = cfg.clients_per_round
    aircomp = alg.aircomp
    n_shards = _cohort_shards(cfg, mesh)

    # ---- compressor statics (DESIGN.md §13): the registry entry only
    # applies to sparsifying AirComp schemes (pfels); wfl_* transmit dense
    # and dp_fedavg/fedavg are digital. Everything here is config-static,
    # so the rand_k default traces the exact pre-registry code paths.
    comp = (compressors.get_compressor(cfg.compressor)
            if aircomp and alg.sparsifies_transmit else None)
    sched = cfg.schedule
    sched_on = comp is not None and compressors.schedules.is_active(sched)
    has_encode = comp is not None and comp.encode is not None
    # carry-compressors (top_k_ef) force error feedback on: without the
    # residual memory, pure top-k starves never-transmitted coordinates
    ef_on = cfg.error_feedback or (comp is not None and comp.carry(cfg))
    c1_scale = comp.sensitivity(cfg, d) if comp is not None else 1.0
    # whether Support.active can be non-None this config (static, so the
    # sharded body's fixed signature knows to consume its ``act`` slot)
    dyn_active = comp is not None and (
        comp.dynamic_support(cfg)
        or (sched_on and sched.k_end_ratio < 1.0))
    # encode must see the CLIPPED update (clip -> quantize -> transmit is
    # the Lemma-2 premise the sensitivity factor is derived under), and
    # error feedback needs the clip scales for the residual — both cases
    # pre-apply the transmit clip and hand the aggregator clip=None
    pre_clip = cfg.transmit_clip is not None and (ef_on or has_encode)
    if comp is not None and comp.decode is not None and n_shards > 1:
        raise ValueError(
            f"compressor {comp.name!r} has a custom decode hook, which "
            f"the sharded-cohort path does not route yet; use "
            f"client_sharding='none' (DESIGN.md §13)")

    train = functools.partial(
        local_train, loss_fn=loss_fn, steps=cfg.local_steps,
        lr=cfg.local_lr, clip=cfg.clip, momentum=cfg.momentum)

    def client_updates(params, cx, cy, ck):
        """Local training (Alg. 2 lines 5-11) vmapped over any client
        slice -> ((r_slice, d) flat updates, (r_slice,) losses)."""
        new_params, losses = jax.vmap(
            lambda x, y, k: train(params, x, y, k))(cx, cy, ck)
        updates = jax.vmap(lambda np_: model_update(params, np_))(new_params)
        flat = jax.vmap(lambda u: ravel_pytree(u)[0])(updates)
        return flat, losses

    def support_and_beta(gains_design, p_sel, prev_delta, idx_key,
                         t=None, eps_spent=None):
        """Registry hooks: support omega_t + β-design, from the GLOBAL (r,)
        gains — shared by both execution paths. ``gains_design`` must be
        ``channels.design_gains(cr)``: the gains the devices actually
        OBSERVE (``gains_obs`` under imperfect CSI — each device transmits
        ``x_i = (beta/h_i^est) A Delta_i``, so its energy is
        ``(beta/h_i^est)^2 ||A Delta_i||^2`` and the Eq. 34c power cap
        only bounds it by ``P_i`` when beta is designed from ``h^est``;
        designing from the true gains violated ``P_i`` whenever
        ``h_i < h_i^est``, regression-tested in
        tests/test_power_control.py), with dropped-out clients lifted so
        they never bind the min (they transmit nothing — the realized-r
        side of the DESIGN.md §11 mask contract).

        With an active :class:`CompressionSchedule` (DESIGN.md §13) the
        round counter ``t`` and the ledger's running spend anneal the
        live-slot column (ANDed into the support), the power limits, and
        the per-round ε ceiling — all traced, zero host round-trips."""
        sup = compressors.as_support(
            alg.select_support(cfg, d, k_coords, prev_delta, idx_key))
        eps_t = None
        if sched_on:
            ka = compressors.schedules.k_active(sched, cfg, k_coords, t)
            if ka is not None:
                sup = compressors.and_active(sup, ka)
            ps = compressors.schedules.power_scale(sched, cfg, t)
            if ps is not None:
                p_sel = p_sel * ps
            eps_t = compressors.schedules.epsilon_round(sched, cfg, t,
                                                        eps_spent)
        k_used = compressors.support_size(sup)
        beta = alg.design_beta(cfg, gains_design, p_sel, d, k_used,
                               epsilon=eps_t, c1_scale=c1_scale)
        return sup, beta, k_used

    cohort_apply = None
    if n_shards > 1:
        spec_c = P(_COHORT_AXES)

        def cohort_body(params, cx_l, cy_l, ck_l, res_l, gains_l, gest_l,
                        mask_l, qk_l, idx, act, beta, noise_key):
            # gains_l is this shard's (r_local, M) per-antenna slice (M=1
            # for scalar channels — bit-exact identity, DESIGN.md §12)
            # inside the manual region: sharding constraints must not
            # re-reference the cohort axes
            with rules.exclude_axes(*_COHORT_AXES):
                flat_l, losses_l = client_updates(params, cx_l, cy_l, ck_l)
            if ef_on:
                flat_l = flat_l + res_l
            tx_l = flat_l
            if aircomp:
                # same once-only clip-scale policy as the vmapped branch:
                # error feedback / encode need the clipped updates anyway,
                # so pre-apply the clip here, hand the aggregator clip=None,
                # and ship the as-transmitted updates back sharded for the
                # residual (compressors.sparsify of tx_l == what went on
                # the air)
                agg_updates, agg_clip = flat_l, cfg.transmit_clip
                if pre_clip:
                    scales_l = transmit_ref.clip_scales(flat_l,
                                                        cfg.transmit_clip)
                    agg_updates = flat_l * scales_l[:, None]
                    agg_clip = None
                if has_encode:
                    agg_updates = comp.encode(cfg, agg_updates, qk_l)
                tx_l = agg_updates
                delta_hat, energy, _ = aggregation.aircomp_aggregate_sharded(
                    agg_updates, idx, gains_l, beta, noise_key, d=d,
                    sigma0=sigma0, r=r, axis_name=_COHORT_AXES,
                    unbiased_rescale=cfg.unbiased_rescale,
                    gains_est_local=(gest_l if cfg.channel.csi_error > 0
                                     else None),
                    clip=agg_clip,
                    use_kernel=cfg.use_fused_kernel,
                    tx_mask_local=(mask_l if has_mask else None),
                    active=(act if dyn_active else None))
            else:
                # dp_fedavg / fedavg aggregate on the gathered updates
                # outside the manual region; only training is sharded
                delta_hat = jnp.zeros((d,), jnp.float32)
                energy = jnp.asarray(0.0, jnp.float32)
            return flat_l, losses_l, tx_l, delta_hat, energy

        cohort_apply = shard_map_compat(
            cohort_body, mesh,
            in_specs=(P(), spec_c, spec_c, spec_c, spec_c, spec_c, spec_c,
                      spec_c, spec_c, P(), P(), P(), P()),
            out_specs=(spec_c, spec_c, spec_c, P(), P()))

    def cohort_core(params, p_sel, cx, cy, ks, res_sel=None,
                    prev_delta=None, chan_carry=None, sel=None,
                    t=None, eps_spent=None):
        ck = jax.random.split(ks[ROUND_KEY_LANES["client_train"]], r)
        # stochastic-rounding keys: fold_in-derived from the support lane
        # (DESIGN.md §5 — the 7-lane round split stays pinned); unused
        # (DCE'd) unless the compressor encodes
        qk = jax.random.split(
            jax.random.fold_in(ks[ROUND_KEY_LANES["support"]],
                               compressors.QUANT_STREAM_TAG), r)

        # ---- channel realization for this round (DESIGN.md §11): the
        # registered model consumes the gains/csi lanes and evolves its
        # cross-round carry; imperfect CSI (beyond paper): clients
        # precompensate with noisy gain estimates while the MAC applies
        # the true gains
        new_chan_carry, cr = chan_model.step(
            chan_carry, cfg.channel, r, sel,
            ks[ROUND_KEY_LANES["gains"]], ks[ROUND_KEY_LANES["csi"]])
        if cr.tx_mask is not None and not has_mask:
            # a silent discard here would let beta design / r_realized see
            # the mask while aggregation ignores it — contradictory
            # numerics; fail at trace time instead
            raise ValueError(
                f"channel model {chan_model.name!r} returned a tx_mask "
                f"but its may_mask(cfg) hook says False — the mask "
                f"plumbing is gated on may_mask (DESIGN.md §11)")
        gains = cr.gains
        gains_obs = channels.observed_gains(cr)
        tx_mask = cr.tx_mask

        sup = beta = None
        k_used = d
        if aircomp:
            # beta designed from what the devices observe (gains_obs ==
            # gains under perfect CSI) — the power cap must hold for the
            # precompensation the devices actually apply — with dropped
            # clients lifted out of the min (design_gains)
            sup, beta, k_used = support_and_beta(
                channels.design_gains(cr), p_sel, prev_delta,
                ks[ROUND_KEY_LANES["support"]],
                t, eps_spent)

        # ---- local training (lines 5-11) + error feedback [28-30]
        # (beyond-paper option, forced on by carry-compressors): add each
        # selected client's residual memory to its update before
        # sparsification; the untransmitted remainder is carried forward
        use_ef = ef_on and res_sel is not None
        agg_sharded = None
        tx_full = None    # the as-transmitted (clipped/encoded) updates
        if cohort_apply is not None:
            res_l = (res_sel if use_ef
                     else jnp.zeros((r, d), jnp.float32))
            gains_mat = (cr.gains_ant if cr.gains_ant is not None
                         else gains[:, None])
            flat_updates, losses, tx_sh, delta_sh, energy_sh = \
                cohort_apply(
                    params, cx, cy, ck, res_l, gains_mat, gains_obs,
                    (tx_mask if tx_mask is not None
                     else jnp.ones((r,), jnp.float32)),
                    qk,
                    sup.idx if sup is not None else jnp.arange(1),
                    (sup.active if sup is not None
                     and sup.active is not None
                     else jnp.ones((1,), jnp.float32)),
                    beta if beta is not None else jnp.asarray(1.0,
                                                              jnp.float32),
                    ks[ROUND_KEY_LANES["channel_noise"]])
            if aircomp:
                agg_sharded = (delta_sh, energy_sh)
                tx_full = tx_sh
        else:
            flat_updates, losses = client_updates(params, cx, cy, ck)
            if use_ef:
                flat_updates = flat_updates + res_sel

        metrics: Dict[str, jnp.ndarray] = {
            "train_loss": jnp.mean(losses),
            "update_norm": jnp.mean(
                jnp.linalg.norm(flat_updates, axis=1)),
            # == r unless the channel model masks transmissions (dropout):
            # the realized transmitter count of the DESIGN.md §11 contract
            "r_realized": channels.realized_cohort_size(cr, r),
        }

        if aircomp:
            if agg_sharded is not None:
                delta_hat, energy = agg_sharded
            else:
                # error feedback needs the clip scales for the residual
                # anyway (and encode must see the clipped update), so
                # compute them ONCE here and hand the aggregator
                # pre-clipped updates (clip=None) instead of paying a second
                # full (r, d) norm sweep inside the fused kernel's
                # client_sumsq pass
                agg_updates, agg_clip = flat_updates, cfg.transmit_clip
                if pre_clip:
                    agg_updates = flat_updates * transmit_ref.clip_scales(
                        flat_updates, cfg.transmit_clip)[:, None]
                    agg_clip = None
                if has_encode:
                    agg_updates = comp.encode(cfg, agg_updates, qk)
                tx_full = agg_updates
                agg_kw = dict(
                    d=d, sigma0=sigma0, r=r,
                    unbiased_rescale=cfg.unbiased_rescale,
                    gains_est=(cr.gains_obs if cfg.channel.csi_error > 0
                               else None),
                    clip=agg_clip, tx_mask=tx_mask,
                    active=sup.active)
                if cfg.use_fused_kernel:
                    # the whole scenario matrix rides the kernel in-tile:
                    # tx_mask as a coefficient column, per-antenna gains
                    # through the MRC combine (DESIGN.md §12)
                    delta_hat, energy, y_agg = \
                        aggregation.aircomp_aggregate_fused(
                            agg_updates, sup.idx, gains, beta,
                            ks[ROUND_KEY_LANES["channel_noise"]],
                            gains_ant=cr.gains_ant, **agg_kw)
                else:
                    delta_hat, energy, y_agg = aggregation.aircomp_aggregate(
                        agg_updates, sup.idx, gains, beta,
                        ks[ROUND_KEY_LANES["channel_noise"]], **agg_kw)
                if comp is not None and comp.decode is not None:
                    # custom server-side reconstruction: the hook replaces
                    # the default A^T unprojection of the k-subcarrier
                    # payload; the 1/(r beta) unscale and the beyond-paper
                    # d/k unbiasing stay the round body's job
                    delta_hat = comp.decode(cfg, y_agg, sup, d) / (
                        aggregation.realized_r(tx_mask, r) * beta)
                    if cfg.unbiased_rescale:
                        delta_hat = delta_hat * (d / k_coords)
            metrics.update(beta=beta, energy=energy,
                           subcarriers=jnp.asarray(k_used))
        else:   # digital server-side aggregation (registry hook)
            # a dropped client uploads nothing in the digital schemes too
            agg_in = (flat_updates * tx_mask[:, None]
                      if tx_mask is not None else flat_updates)
            delta_hat = alg.server_aggregate(
                cfg, agg_in, ks[ROUND_KEY_LANES["channel_noise"]],
                d=d, r=r)
            if tx_mask is not None:
                # same realized-r contract as the AirComp paths: the hook
                # averaged over the nominal r, so rescale to the mean of
                # the updates actually RECEIVED (for dp_fedavg this also
                # scales its noise by r/r_eff >= 1 — conservative). An
                # all-dropped round received NOTHING: apply no update
                # rather than an r-fold-amplified pure-noise step
                delta_hat = jnp.where(
                    jnp.sum(tx_mask) > 0,
                    delta_hat * (r / aggregation.realized_r(tx_mask, r)),
                    jnp.zeros_like(delta_hat))
            metrics.update(beta=jnp.asarray(0.0), energy=jnp.asarray(0.0),
                           subcarriers=jnp.asarray(d))

        # ---- error-feedback memory update: e_i <- u_i - A^T A q(s_i u_i)
        # — the residual is the raw update minus what was ACTUALLY sent
        # (clipped, encoded, projected onto the live support), so the
        # clipped-away / quantization-lost fraction stays in the memory.
        # ``compressors.sparsify`` is THE projection definition every
        # aggregation path shares (ISSUE 7 satellite: this block no longer
        # re-implements it). ``tx_full`` is the as-transmitted (r, d)
        # batch from whichever path aggregated — for the plain rand_k +
        # no-clip config it IS flat_updates, tracing the seed-exact code.
        # Returned as the (r, d) cohort slice; the caller (ClientBank)
        # owns the scatter into the (n, d) bank.
        new_res_sel = res_sel
        if use_ef:
            base = tx_full if (aircomp and tx_full is not None) \
                else flat_updates
            if alg.sparsifies_transmit:
                transmitted = jax.vmap(
                    lambda u: compressors.sparsify(u, sup, d))(base)
            else:
                transmitted = base
            if tx_mask is not None:
                # a dropped client transmitted NOTHING: its whole update
                # stays in the residual memory for its next participation
                transmitted = transmitted * tx_mask[:, None]
            new_res_sel = flat_updates - transmitted

        # ---- server update (line 16)
        flat_params, _ = ravel_pytree(params)
        new_flat = flat_params + delta_hat
        return unravel(new_flat), metrics, new_res_sel, delta_hat, \
            new_chan_carry

    return cohort_core


def _build_round_core(cfg: PFELSConfig, loss_fn: Callable, d: int,
                      unravel: Callable, mesh: Optional[Mesh] = None,
                      cohort_core: Optional[Callable] = None):
    """Population-tensor round body — the pre-bank contract
    ``round_core(params, power_limits, data_x, data_y, key, residuals,
    prev_delta) -> (new_params, metrics, new_residuals, delta_hat)`` —
    now a thin shell over :func:`_build_cohort_core`: split the round key,
    sample the cohort (Alg. 2 line 2), gather the ``sel`` slices, run the
    cohort core, scatter the residual slice back. Backs the deprecated
    ``make_round_fn``/``make_training_fn`` shims (bit-identical under the
    same key). ``cohort_core`` reuses an already-built core (the Trainer
    shares one between its bank paths and these shims)."""
    if cohort_core is None:
        cohort_core = _build_cohort_core(cfg, loss_fn, d, unravel, mesh)
    r = cfg.clients_per_round

    def round_core(params, power_limits, data_x, data_y, key,
                   residuals=None, prev_delta=None):
        ks = split_round_key(key)
        sel = sample_cohort(ks[ROUND_KEY_LANES["selection"]],
                            cfg.num_clients, r)
        res_sel = (residuals[sel]
                   if cfg.error_feedback and residuals is not None
                   else None)
        # the legacy contract has nowhere to carry cross-round channel
        # state; stateless models take carry=None (make_round_fn /
        # make_training_fn reject stateful ones up front)
        new_params, metrics, new_res_sel, delta_hat, _ = cohort_core(
            params, power_limits[sel], data_x[sel], data_y[sel], ks,
            res_sel, prev_delta, None, sel)
        new_residuals = residuals
        if new_res_sel is not None and residuals is not None:
            new_residuals = residuals.at[sel].set(new_res_sel)
        return new_params, metrics, new_residuals, delta_hat

    return round_core


def _legacy_trainer(cfg: PFELSConfig, loss_fn: Callable, d: int,
                    unravel: Callable, mesh: Optional[Mesh]):
    """The Trainer a legacy shim delegates to (lazy import: api.py imports
    this module for the round core)."""
    from repro.fl.api import Trainer
    return Trainer(cfg, loss_fn, unravel(jnp.zeros((d,), jnp.float32)),
                   mesh=mesh)


def _reject_stateful_channel(cfg: PFELSConfig, shim: str):
    """The deprecated shims carry no cross-round channel state — a
    stateful channel model (markov_fading) would silently re-initialize
    every round, so they refuse it; the Trainer carries it in
    ``TrainState.chan`` (DESIGN.md §11)."""
    model = channels.get_channel_model(cfg.channel.model)
    if model.stateful(cfg.channel):
        raise ValueError(
            f"channel model {cfg.channel.model!r} is stateful and the "
            f"deprecated {shim} has nowhere to carry its cross-round "
            f"state; use repro.fl.Trainer (DESIGN.md §11)")


def _reject_legacy_compression(cfg: PFELSConfig, shim: str):
    """The deprecated shims predate the compressor registry: a
    CompressionSchedule needs the round counter and the running ε spend
    (which only ``TrainState`` carries), and a carry-compressor
    (top_k_ef) needs the bank's residual memory the shim only allocates
    under ``cfg.error_feedback`` — refuse both rather than silently
    running a different scheme (DESIGN.md §13)."""
    alg = algorithms.get_algorithm(cfg.algorithm)
    if not (alg.aircomp and alg.sparsifies_transmit):
        return
    if compressors.schedules.is_active(cfg.schedule):
        raise ValueError(
            f"cfg.schedule.mode={cfg.schedule.mode!r} needs the round "
            f"counter and privacy-ledger state that the deprecated "
            f"{shim} has nowhere to carry; use repro.fl.Trainer "
            f"(DESIGN.md §13)")
    if compressors.carry_required(cfg) and not cfg.error_feedback:
        raise ValueError(
            f"compressor {cfg.compressor!r} requires error-feedback "
            f"residuals but the deprecated {shim} only allocates them "
            f"with cfg.error_feedback=True; set error_feedback=True or "
            f"use repro.fl.Trainer (DESIGN.md §13)")


def make_round_fn(cfg: PFELSConfig, loss_fn: Callable, d: int,
                  unravel: Callable, mesh: Optional[Mesh] = None):
    """DEPRECATED legacy single-round entry — a thin shim over
    :class:`repro.fl.api.Trainer` (``Trainer.step`` is the replacement; it
    has ONE signature and return shape regardless of config). Kept
    bit-identical under the same key for the golden-parity tests.

    loss_fn(params, {"x","y"}) -> (loss, aux). d = flat dim; unravel maps a
    flat (d,) vector back to the params pytree. Returns
    ``(params, metrics)`` or, with ``cfg.error_feedback``,
    ``(params, metrics, residuals)`` — the config-dependent arity the new
    API removes.

    ``mesh``: cohort mesh for ``cfg.client_sharding="cohort"`` (defaults to
    ``make_cohort_mesh(cfg.clients_per_round)`` over the visible devices);
    ignored with ``client_sharding="none"``.
    """
    warnings.warn(
        "repro.fl.make_round_fn is deprecated; use repro.fl.Trainer.step "
        "(DESIGN.md §8)", DeprecationWarning, stacklevel=2)
    _reject_stateful_channel(cfg, "make_round_fn")
    _reject_legacy_compression(cfg, "make_round_fn")
    trainer = _legacy_trainer(cfg, loss_fn, d, unravel, mesh)
    core = trainer._core
    leaks_delta_hat = (cfg.randk_mode == "server_topk"
                       and trainer.algorithm.aircomp)
    if leaks_delta_hat:
        warnings.warn(
            "the 'delta_hat' metrics key is deprecated (it stacks to a "
            "(T, d) buffer under scan); read TrainState.prev_delta from "
            "Trainer.step/run instead", DeprecationWarning, stacklevel=2)

    def round_fn(params, power_limits, data_x, data_y, key,
                 residuals=None, prev_delta=None):
        new_params, metrics, new_residuals, delta_hat = core(
            params, power_limits, data_x, data_y, key, residuals,
            prev_delta)
        if leaks_delta_hat:
            metrics["delta_hat"] = delta_hat  # seed-era consumer contract
        if cfg.error_feedback:
            return new_params, metrics, new_residuals
        return new_params, metrics

    return jax.jit(round_fn)


def make_training_fn(cfg: PFELSConfig, loss_fn: Callable, d: int,
                     unravel: Callable, rounds: Optional[int] = None,
                     mesh: Optional[Mesh] = None):
    """DEPRECATED legacy T-round ``lax.scan`` driver — a thin shim over
    :class:`repro.fl.api.Trainer` (``Trainer.run`` is the replacement: same
    one-program scan, plus the in-graph privacy ledger and automatic
    chunked-resume state). Kept bit-identical under the same key for the
    golden-parity tests.

    Returns ``training_fn(params, power_limits, data_x, data_y, key,
    residuals=None, prev_delta=None) -> (params_T, metrics_T, residuals_T,
    delta_T)`` where every ``metrics_T`` leaf is stacked over the T rounds
    (leading axis T) and ``delta_T`` is the last round's reconstructed
    update — feed it (and ``residuals_T``) back in to resume chunked
    training. ``rounds`` defaults to ``cfg.rounds``; ``mesh`` as in
    :func:`make_round_fn`.
    """
    warnings.warn(
        "repro.fl.make_training_fn is deprecated; use repro.fl.Trainer.run "
        "(DESIGN.md §8)", DeprecationWarning, stacklevel=2)
    _reject_stateful_channel(cfg, "make_training_fn")
    _reject_legacy_compression(cfg, "make_training_fn")
    t_rounds = cfg.rounds if rounds is None else rounds
    trainer = _legacy_trainer(cfg, loss_fn, d, unravel, mesh)
    core = trainer._core

    def training_fn(params, power_limits, data_x, data_y, key,
                    residuals=None, prev_delta=None):
        if cfg.error_feedback and residuals is None:
            residuals = jnp.zeros((cfg.num_clients, d), jnp.float32)
        if prev_delta is None:
            prev_delta = jnp.zeros((d,), jnp.float32)

        def body(carry, round_key):
            p, res, prev = carry
            p2, metrics, res2, delta_hat = core(
                p, power_limits, data_x, data_y, round_key, res, prev)
            return (p2, res2, delta_hat), metrics

        keys = jax.random.split(key, t_rounds)
        (p_final, res_final, delta_final), metrics = jax.lax.scan(
            body, (params, residuals, prev_delta), keys)
        return p_final, metrics, res_final, delta_final

    return jax.jit(training_fn)


def round_epsilon_spent(cfg: PFELSConfig, beta: float,
                        d: Optional[int] = None) -> float:
    """Per-round eps actually consumed (Thm 3 inverse), for the ledger.
    Uses the channel model's POST-COMBINING noise std (== the raw sigma_0
    for single-antenna models): the intrinsic noise that actually
    perturbs the aggregate is what the DP guarantee rides on
    (DESIGN.md §11) — and, for sparsifying AirComp schemes, C1 scaled by
    the configured compressor's sensitivity factor (DESIGN.md §13), so
    host recomputations (``PrivacyLedger``) reproduce the in-graph spend
    exactly; ``d`` feeds dimension-dependent factors (stoch_quant)."""
    alg = algorithms.get_algorithm(cfg.algorithm)
    s = (compressors.sensitivity_factor(cfg, d)
         if alg.aircomp and alg.sparsifies_transmit else 1.0)
    return privacy.round_epsilon(
        beta, cfg.local_lr, cfg.local_steps, cfg.clip * s,
        cfg.clients_per_round, cfg.num_clients, cfg.resolved_delta(),
        channels.effective_noise_std(cfg.channel))


def evaluate(params, loss_fn, xt, yt, batch: int = 256):
    """Test accuracy over the held-out set."""
    n = xt.shape[0]
    accs, losses = [], []
    for i in range(0, n, batch):
        loss, aux = loss_fn(params, {"x": xt[i:i + batch],
                                     "y": yt[i:i + batch]})
        accs.append(aux["accuracy"] * min(batch, n - i))
        losses.append(loss * min(batch, n - i))
    return (float(sum(losses)) / n, float(sum(accs)) / n)
