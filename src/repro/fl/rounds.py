"""The full PFELS round (Alg. 2) and baselines, simulation mode.

One jitted ``round_fn`` runs: sample r clients -> vmapped local training ->
rand_k projection -> Theorem-5 power control -> AirComp over the simulated
MAC -> server update. Baselines (WFL-P Eq. 36, WFL-PDP Eq. 37, DP-FedAvg
Alg. 1, FedAvg) share the same structure with their own aggregation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs.base import PFELSConfig
from repro.core import aggregation, channel, power_control, privacy, randk
from repro.fl.client import local_train, model_update
from repro.kernels.pfels_transmit import ref as transmit_ref


@dataclass
class FLState:
    params: Any
    power_limits: jnp.ndarray       # (N,) P_i, fixed per device
    residuals: Any = None           # (N, d) error-feedback memory [28-30]
    round: int = 0


def setup(key, params, cfg: PFELSConfig, d: int) -> FLState:
    kp, = jax.random.split(key, 1)
    p_lim = channel.sample_power_limits(kp, cfg.num_clients, d, cfg.channel)
    res = (jnp.zeros((cfg.num_clients, d), jnp.float32)
           if cfg.error_feedback else None)
    return FLState(params=params, power_limits=p_lim, residuals=res)


def _build_round_core(cfg: PFELSConfig, loss_fn: Callable, d: int,
                      unravel: Callable):
    """The raw (un-jitted) round body, uniform across algorithms: returns
    ``(new_params, metrics, new_residuals, delta_hat)`` so it can back both
    the single-round ``make_round_fn`` wrapper and the ``lax.scan`` driver
    in ``make_training_fn``."""
    k_coords = max(int(round(cfg.compression_ratio * d)), 1)
    alg = cfg.algorithm
    delta = cfg.resolved_delta()
    sigma0 = cfg.channel.noise_std
    r = cfg.clients_per_round

    def round_core(params, power_limits, data_x, data_y, key,
                   residuals=None, prev_delta=None):
        ks = jax.random.split(key, 7)
        # ---- sample r clients without replacement (Alg. 2 line 2)
        sel = jax.random.choice(ks[0], cfg.num_clients, (r,), replace=False)
        cx, cy = data_x[sel], data_y[sel]
        p_sel = power_limits[sel]

        # ---- local training (lines 5-11), vmapped over clients
        ck = jax.random.split(ks[1], r)
        train = functools.partial(
            local_train, loss_fn=loss_fn, steps=cfg.local_steps,
            lr=cfg.local_lr, clip=cfg.clip, momentum=cfg.momentum)
        new_params, losses = jax.vmap(
            lambda x, y, k: train(params, x, y, k))(cx, cy, ck)
        updates = jax.vmap(lambda np_: model_update(params, np_))(new_params)
        flat_updates = jax.vmap(lambda u: ravel_pytree(u)[0])(updates)

        # ---- error feedback [28-30] (beyond-paper option): add each
        # selected client's residual memory to its update before
        # sparsification; the untransmitted remainder is carried forward
        if cfg.error_feedback and residuals is not None:
            flat_updates = flat_updates + residuals[sel]

        # ---- channel state for this round (§4.1)
        gains = channel.sample_gains(ks[2], r, cfg.channel)

        metrics: Dict[str, jnp.ndarray] = {
            "train_loss": jnp.mean(losses),
            "update_norm": jnp.mean(
                jnp.linalg.norm(flat_updates, axis=1)),
        }

        # imperfect CSI (beyond paper): clients precompensate with noisy
        # gain estimates while the MAC applies the true gains
        gains_est = channel.estimate_gains(ks[6], gains, cfg.channel)

        if alg in ("pfels", "wfl_p", "wfl_pdp"):
            if alg == "pfels":
                if cfg.randk_mode == "server_topk" and prev_delta is not None:
                    # server-guided top-k (beyond paper): half the budget on
                    # the top coords of |Delta_hat_{t-1}| (shared across
                    # clients -> AirComp alignment preserved), half explored
                    # uniformly — pure top-k locks its support (coords never
                    # transmitted keep |Delta_hat|=0 and are never selected).
                    # A zero prev_delta (the scan driver's cold start) falls
                    # back to the uniform sample — top_k over |zeros| would
                    # deterministically pick coords 0..k1-1, biasing round 1.
                    def _warm_idx():
                        k1 = k_coords // 2
                        _, idx_top = jax.lax.top_k(jnp.abs(prev_delta), k1)
                        scores = jax.random.uniform(ks[3], (d,))
                        scores = scores.at[idx_top].set(-jnp.inf)
                        _, idx_rand = jax.lax.top_k(scores, k_coords - k1)
                        return jnp.concatenate([idx_top, idx_rand])

                    idx = jax.lax.cond(
                        jnp.linalg.norm(prev_delta) > 0, _warm_idx,
                        lambda: randk.sample_indices(ks[3], d, k_coords))
                else:
                    idx = randk.sample_indices(ks[3], d, k_coords)
                beta = power_control.beta_pfels(
                    gains, p_sel, d=d, k=k_coords, c1=cfg.clip,
                    eta=cfg.local_lr, tau=cfg.local_steps,
                    epsilon=cfg.epsilon, r=r, n=cfg.num_clients,
                    delta=delta, sigma0=sigma0)
                k_used = k_coords
            else:
                idx = jnp.arange(d)
                k_used = d
                if alg == "wfl_p":
                    beta = power_control.beta_wfl_p(
                        gains, p_sel, c1=cfg.clip, eta=cfg.local_lr,
                        tau=cfg.local_steps)
                else:
                    beta = power_control.beta_wfl_pdp(
                        gains, p_sel, c1=cfg.clip, eta=cfg.local_lr,
                        tau=cfg.local_steps, epsilon=cfg.epsilon, r=r,
                        n=cfg.num_clients, delta=delta, sigma0=sigma0)
            aggregate = (aggregation.aircomp_aggregate_fused
                         if cfg.use_fused_kernel
                         else aggregation.aircomp_aggregate)
            # error feedback needs the clip scales for the residual anyway,
            # so compute them ONCE here and hand the aggregator pre-clipped
            # updates (clip=None) instead of paying a second full (r, d)
            # norm sweep inside the fused kernel's client_sumsq pass
            agg_updates, agg_clip = flat_updates, cfg.transmit_clip
            if cfg.transmit_clip is not None and cfg.error_feedback:
                transmit_scales = transmit_ref.clip_scales(
                    flat_updates, cfg.transmit_clip)
                agg_updates = flat_updates * transmit_scales[:, None]
                agg_clip = None
            delta_hat, energy, _ = aggregate(
                agg_updates, idx, gains, beta, ks[4], d=d, sigma0=sigma0,
                r=r, unbiased_rescale=cfg.unbiased_rescale,
                gains_est=gains_est if cfg.channel.csi_error > 0 else None,
                clip=agg_clip)
            metrics.update(beta=beta, energy=energy,
                           subcarriers=jnp.asarray(k_used))
        elif alg == "dp_fedavg":
            delta_hat = aggregation.dp_fedavg_aggregate(
                flat_updates, cfg.clip, cfg.dp_fedavg_sigma, ks[4], r=r)
            metrics.update(beta=jnp.asarray(0.0), energy=jnp.asarray(0.0),
                           subcarriers=jnp.asarray(d))
        else:  # fedavg
            delta_hat = aggregation.fedavg_aggregate(flat_updates)
            metrics.update(beta=jnp.asarray(0.0), energy=jnp.asarray(0.0),
                           subcarriers=jnp.asarray(d))

        # ---- error-feedback memory update: e_i <- u_i - s_i A^T A u_i,
        # where s_i is the transmit clip scale — what was actually sent is
        # the clipped sparsified update, so the clipped-away fraction stays
        # in the residual memory too
        new_residuals = residuals
        if cfg.error_feedback and residuals is not None:
            if alg == "pfels":
                transmitted = jax.vmap(
                    lambda u: randk.sparsify(u, idx, d))(flat_updates)
            else:
                transmitted = flat_updates
            if (cfg.transmit_clip is not None
                    and alg in ("pfels", "wfl_p", "wfl_pdp")):
                transmitted = transmitted * transmit_scales[:, None]
            new_residuals = residuals.at[sel].set(
                flat_updates - transmitted)

        # ---- server update (line 16)
        flat_params, _ = ravel_pytree(params)
        new_flat = flat_params + delta_hat
        return unravel(new_flat), metrics, new_residuals, delta_hat

    return round_core


def make_round_fn(cfg: PFELSConfig, loss_fn: Callable, d: int,
                  unravel: Callable):
    """Builds the jitted single-round function.

    loss_fn(params, {"x","y"}) -> (loss, aux). d = flat dim; unravel maps a
    flat (d,) vector back to the params pytree. Returns
    ``(params, metrics)`` or, with ``cfg.error_feedback``,
    ``(params, metrics, residuals)``.
    """
    core = _build_round_core(cfg, loss_fn, d, unravel)

    def round_fn(params, power_limits, data_x, data_y, key,
                 residuals=None, prev_delta=None):
        new_params, metrics, new_residuals, delta_hat = core(
            params, power_limits, data_x, data_y, key, residuals,
            prev_delta)
        if (cfg.randk_mode == "server_topk"
                and cfg.algorithm in ("pfels", "wfl_p", "wfl_pdp")):
            metrics["delta_hat"] = delta_hat  # seed-era consumer contract
        if cfg.error_feedback:
            return new_params, metrics, new_residuals
        return new_params, metrics

    return jax.jit(round_fn)


def make_training_fn(cfg: PFELSConfig, loss_fn: Callable, d: int,
                     unravel: Callable, rounds: int = None):
    """Builds a jitted T-round driver: one ``lax.scan`` over rounds in a
    single compiled program, carrying ``(params, residuals, prev_delta)``
    state — long simulations stop paying per-round dispatch/retrace
    overhead.

    Returns ``training_fn(params, power_limits, data_x, data_y, key,
    residuals=None, prev_delta=None) -> (params_T, metrics_T, residuals_T,
    delta_T)`` where every ``metrics_T`` leaf is stacked over the T rounds
    (leading axis T) and ``delta_T`` is the last round's reconstructed
    update — feed it (and ``residuals_T``) back in to resume chunked
    training without resetting the server_topk support or the
    error-feedback memory. ``rounds`` defaults to ``cfg.rounds``.
    """
    t_rounds = cfg.rounds if rounds is None else rounds
    core = _build_round_core(cfg, loss_fn, d, unravel)

    def training_fn(params, power_limits, data_x, data_y, key,
                    residuals=None, prev_delta=None):
        if cfg.error_feedback and residuals is None:
            residuals = jnp.zeros((cfg.num_clients, d), jnp.float32)
        if prev_delta is None:
            prev_delta = jnp.zeros((d,), jnp.float32)

        def body(carry, round_key):
            p, res, prev = carry
            p2, metrics, res2, delta_hat = core(
                p, power_limits, data_x, data_y, round_key, res, prev)
            return (p2, res2, delta_hat), metrics

        keys = jax.random.split(key, t_rounds)
        (p_final, res_final, delta_final), metrics = jax.lax.scan(
            body, (params, residuals, prev_delta), keys)
        return p_final, metrics, res_final, delta_final

    return jax.jit(training_fn)


def round_epsilon_spent(cfg: PFELSConfig, beta: float) -> float:
    """Per-round eps actually consumed (Thm 3 inverse), for the ledger."""
    return privacy.round_epsilon(
        beta, cfg.local_lr, cfg.local_steps, cfg.clip,
        cfg.clients_per_round, cfg.num_clients, cfg.resolved_delta(),
        cfg.channel.noise_std)


def evaluate(params, loss_fn, xt, yt, batch: int = 256):
    """Test accuracy over the held-out set."""
    n = xt.shape[0]
    accs, losses = [], []
    for i in range(0, n, batch):
        loss, aux = loss_fn(params, {"x": xt[i:i + batch],
                                     "y": yt[i:i + batch]})
        accs.append(aux["accuracy"] * min(batch, n - i))
        losses.append(loss * min(batch, n - i))
    return (float(sum(losses)) / n, float(sum(accs)) / n)
