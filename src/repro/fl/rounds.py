"""The full PFELS round (Alg. 2) and baselines, simulation mode.

One jitted ``round_fn`` runs: sample r clients -> vmapped local training ->
rand_k projection -> Theorem-5 power control -> AirComp over the simulated
MAC -> server update. Baselines (WFL-P Eq. 36, WFL-PDP Eq. 37, DP-FedAvg
Alg. 1, FedAvg) share the same structure with their own aggregation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs.base import PFELSConfig
from repro.core import aggregation, channel, power_control, privacy, randk
from repro.fl.client import local_train, model_update


@dataclass
class FLState:
    params: Any
    power_limits: jnp.ndarray       # (N,) P_i, fixed per device
    residuals: Any = None           # (N, d) error-feedback memory [28-30]
    round: int = 0


def setup(key, params, cfg: PFELSConfig, d: int) -> FLState:
    kp, = jax.random.split(key, 1)
    p_lim = channel.sample_power_limits(kp, cfg.num_clients, d, cfg.channel)
    res = (jnp.zeros((cfg.num_clients, d), jnp.float32)
           if cfg.error_feedback else None)
    return FLState(params=params, power_limits=p_lim, residuals=res)


def make_round_fn(cfg: PFELSConfig, loss_fn: Callable, d: int,
                  unravel: Callable):
    """Builds the jitted round function.

    loss_fn(params, {"x","y"}) -> (loss, aux). d = flat dim; unravel maps a
    flat (d,) vector back to the params pytree.
    """
    k_coords = max(int(round(cfg.compression_ratio * d)), 1)
    alg = cfg.algorithm
    delta = cfg.resolved_delta()
    sigma0 = cfg.channel.noise_std
    r = cfg.clients_per_round

    def round_fn(params, power_limits, data_x, data_y, key,
                 residuals=None, prev_delta=None):
        ks = jax.random.split(key, 7)
        # ---- sample r clients without replacement (Alg. 2 line 2)
        sel = jax.random.choice(ks[0], cfg.num_clients, (r,), replace=False)
        cx, cy = data_x[sel], data_y[sel]
        p_sel = power_limits[sel]

        # ---- local training (lines 5-11), vmapped over clients
        ck = jax.random.split(ks[1], r)
        train = functools.partial(
            local_train, loss_fn=loss_fn, steps=cfg.local_steps,
            lr=cfg.local_lr, clip=cfg.clip, momentum=cfg.momentum)
        new_params, losses = jax.vmap(
            lambda x, y, k: train(params, x, y, k))(cx, cy, ck)
        updates = jax.vmap(lambda np_: model_update(params, np_))(new_params)
        flat_updates = jax.vmap(lambda u: ravel_pytree(u)[0])(updates)

        # ---- error feedback [28-30] (beyond-paper option): add each
        # selected client's residual memory to its update before
        # sparsification; the untransmitted remainder is carried forward
        if cfg.error_feedback and residuals is not None:
            flat_updates = flat_updates + residuals[sel]

        # ---- channel state for this round (§4.1)
        gains = channel.sample_gains(ks[2], r, cfg.channel)

        metrics: Dict[str, jnp.ndarray] = {
            "train_loss": jnp.mean(losses),
            "update_norm": jnp.mean(
                jnp.linalg.norm(flat_updates, axis=1)),
        }

        # imperfect CSI (beyond paper): clients precompensate with noisy
        # gain estimates while the MAC applies the true gains
        gains_est = channel.estimate_gains(ks[6], gains, cfg.channel)

        if alg in ("pfels", "wfl_p", "wfl_pdp"):
            if alg == "pfels":
                if cfg.randk_mode == "server_topk" and prev_delta is not None:
                    # server-guided top-k (beyond paper): half the budget on
                    # the top coords of |Delta_hat_{t-1}| (shared across
                    # clients -> AirComp alignment preserved), half explored
                    # uniformly — pure top-k locks its support (coords never
                    # transmitted keep |Delta_hat|=0 and are never selected)
                    k1 = k_coords // 2
                    _, idx_top = jax.lax.top_k(jnp.abs(prev_delta), k1)
                    scores = jax.random.uniform(ks[3], (d,))
                    scores = scores.at[idx_top].set(-jnp.inf)
                    _, idx_rand = jax.lax.top_k(scores, k_coords - k1)
                    idx = jnp.concatenate([idx_top, idx_rand])
                else:
                    idx = randk.sample_indices(ks[3], d, k_coords)
                beta = power_control.beta_pfels(
                    gains, p_sel, d=d, k=k_coords, c1=cfg.clip,
                    eta=cfg.local_lr, tau=cfg.local_steps,
                    epsilon=cfg.epsilon, r=r, n=cfg.num_clients,
                    delta=delta, sigma0=sigma0)
                k_used = k_coords
            else:
                idx = jnp.arange(d)
                k_used = d
                if alg == "wfl_p":
                    beta = power_control.beta_wfl_p(
                        gains, p_sel, c1=cfg.clip, eta=cfg.local_lr,
                        tau=cfg.local_steps)
                else:
                    beta = power_control.beta_wfl_pdp(
                        gains, p_sel, c1=cfg.clip, eta=cfg.local_lr,
                        tau=cfg.local_steps, epsilon=cfg.epsilon, r=r,
                        n=cfg.num_clients, delta=delta, sigma0=sigma0)
            delta_hat, energy, _ = aggregation.aircomp_aggregate(
                flat_updates, idx, gains, beta, ks[4], d=d, sigma0=sigma0,
                r=r, unbiased_rescale=cfg.unbiased_rescale,
                gains_est=gains_est if cfg.channel.csi_error > 0 else None)
            metrics.update(beta=beta, energy=energy,
                           subcarriers=jnp.asarray(k_used))
            if cfg.randk_mode == "server_topk":
                metrics["delta_hat"] = delta_hat
        elif alg == "dp_fedavg":
            delta_hat = aggregation.dp_fedavg_aggregate(
                flat_updates, cfg.clip, cfg.dp_fedavg_sigma, ks[4], r=r)
            metrics.update(beta=jnp.asarray(0.0), energy=jnp.asarray(0.0),
                           subcarriers=jnp.asarray(d))
        else:  # fedavg
            delta_hat = aggregation.fedavg_aggregate(flat_updates)
            metrics.update(beta=jnp.asarray(0.0), energy=jnp.asarray(0.0),
                           subcarriers=jnp.asarray(d))

        # ---- error-feedback memory update: e_i <- u_i - A^T A u_i
        new_residuals = residuals
        if cfg.error_feedback and residuals is not None:
            if alg == "pfels":
                transmitted = jax.vmap(
                    lambda u: randk.sparsify(u, idx, d))(flat_updates)
            else:
                transmitted = flat_updates
            new_residuals = residuals.at[sel].set(
                flat_updates - transmitted)

        # ---- server update (line 16)
        flat_params, _ = ravel_pytree(params)
        new_flat = flat_params + delta_hat
        if cfg.error_feedback:
            return unravel(new_flat), metrics, new_residuals
        return unravel(new_flat), metrics

    return jax.jit(round_fn)


def round_epsilon_spent(cfg: PFELSConfig, beta: float) -> float:
    """Per-round eps actually consumed (Thm 3 inverse), for the ledger."""
    return privacy.round_epsilon(
        beta, cfg.local_lr, cfg.local_steps, cfg.clip,
        cfg.clients_per_round, cfg.num_clients, cfg.resolved_delta(),
        cfg.channel.noise_std)


def evaluate(params, loss_fn, xt, yt, batch: int = 256):
    """Test accuracy over the held-out set."""
    n = xt.shape[0]
    accs, losses = [], []
    for i in range(0, n, batch):
        loss, aux = loss_fn(params, {"x": xt[i:i + batch],
                                     "y": yt[i:i + batch]})
        accs.append(aux["accuracy"] * min(batch, n - i))
        losses.append(loss * min(batch, n - i))
    return (float(sum(losses)) / n, float(sum(accs)) / n)
