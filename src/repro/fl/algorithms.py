"""Pluggable transmit-scheme registry for the FL round (DESIGN.md §8).

Each :class:`Algorithm` entry supplies the three points where the paper's
schemes actually differ — support selection (which coordinates are
transmitted), β-design (the per-round power/alignment coefficient), and
aggregation — plus the per-round privacy spend charged to the in-graph
ledger. The round body in ``repro.fl.rounds._build_round_core`` is
otherwise uniform: local training, error feedback, the AirComp machinery
(unfused / fused Pallas / sharded cohort), metrics, and the server update
are shared by every entry, so a new transmit scheme is a
``register_algorithm`` call, not another ``cfg.algorithm`` branch.

Built-in entries reproduce the paper: ``pfels`` (Alg. 2 + Thm 5),
``wfl_p`` (Eq. 36), ``wfl_pdp`` (Eq. 37), ``dp_fedavg`` (paper Alg. 1),
``fedavg``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax.numpy as jnp

from repro.configs.base import PFELSConfig
from repro.core import (aggregation, channels, compressors, power_control,
                        privacy)


@dataclass(frozen=True)
class Algorithm:
    """One transmit scheme.

    ``aircomp=True`` routes the round through the analog AirComp path
    (support selection -> β-design -> MAC superposition, with the
    config-selected execution strategy: unfused reference, fused Pallas
    kernel, or sharded cohort psum); the entry must then provide
    ``select_support`` and ``design_beta``. ``aircomp=False`` means digital
    server-side aggregation; the entry must provide ``server_aggregate``.

    Hooks (all trace-safe):
      select_support(cfg, d, k, prev_delta, key)
          -> repro.core.compressors.Support
          the transmitted coordinate set omega_t (static-width ``idx``
          plus an optional 0/1 ``active`` live-slot column, DESIGN.md
          §13); ``prev_delta`` is the previous round's reconstructed
          update (zeros on cold start) for server-guided schemes.
          Sparsifying schemes (pfels) delegate to the configured
          ``repro.core.compressors`` registry entry.
      design_beta(cfg, gains, power_limits, d, k_used, *, epsilon=None,
                  c1_scale=1.0) -> scalar beta
          the per-round alignment coefficient from the GLOBAL (r,) gains
          and the selected clients' power limits. ``k_used`` may be a
          traced live-support count; ``epsilon`` overrides the per-round
          budget (the "budget" CompressionSchedule); ``c1_scale`` is the
          compressor's static sensitivity multiplier on C1 (DESIGN.md
          §13) — both caps are linear in C1, so it tightens the power
          AND privacy constraints consistently.
      server_aggregate(cfg, flat_updates, noise_key, *, d, r) -> (d,)
          digital aggregation of the (r, d) update batch.
      privacy_spend(cfg, beta, d=None) -> scalar eps
          per-round (eps, cfg.resolved_delta())-DP charge for the realized
          beta, accumulated by the in-graph ledger; ``d`` feeds
          dimension-dependent compressor sensitivity (stoch_quant).
          None = the scheme carries no per-round DP guarantee and is
          never ledgered.

    ``sparsifies_transmit`` tells the error-feedback memory whether the
    transmitted signal was restricted to the support (residual = the
    untransmitted coordinates) or dense.
    """
    name: str
    aircomp: bool
    select_support: Optional[Callable] = None
    design_beta: Optional[Callable] = None
    server_aggregate: Optional[Callable] = None
    privacy_spend: Optional[Callable] = None
    sparsifies_transmit: bool = False


_REGISTRY: Dict[str, Algorithm] = {}


def register_algorithm(name: str, alg: Algorithm, *,
                       overwrite: bool = False) -> Algorithm:
    """Add a transmit scheme under ``PFELSConfig.algorithm == name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"algorithm {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    if alg.aircomp and (alg.select_support is None or alg.design_beta is None):
        raise ValueError(f"aircomp algorithm {name!r} needs select_support "
                         f"and design_beta hooks")
    if not alg.aircomp and alg.server_aggregate is None:
        raise ValueError(f"non-aircomp algorithm {name!r} needs a "
                         f"server_aggregate hook")
    _REGISTRY[name] = alg
    return alg


def unregister_algorithm(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_algorithm(name: str) -> Algorithm:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: "
            f"{sorted(_REGISTRY)} (add new schemes via "
            f"repro.fl.algorithms.register_algorithm)") from None


def list_algorithms():
    return sorted(_REGISTRY)


# ------------------------------------------------------- built-in schemes

def _dp_epsilon_spend(cfg: PFELSConfig, beta, d=None, *,
                      compressed: bool = True):
    """Per-round eps actually consumed (Thm 3 inverse) for the realized
    beta, capped at the configured budget — Theorem 5 already enforces
    ``C2 * beta <= eps``, so the cap only absorbs fp rounding (and matches
    the host-side ledger convention of the legacy drivers). C2 is built
    from the channel model's POST-COMBINING noise std (DESIGN.md §11):
    a multi-antenna receiver changes the intrinsic noise the guarantee
    rides on, and the ledger must charge against that operating point —
    and, for compressed (sparsifying) schemes, from ``C1`` scaled by the
    compressor's static sensitivity factor (DESIGN.md §13): C2 is linear
    in C1, so a norm-inflating transform (stoch_quant) costs
    proportionally more budget per unit beta. ``d`` feeds
    dimension-dependent factors; rand_k's factor is 1.0, making this
    bit-identical to the pre-registry spend."""
    s = compressors.sensitivity_factor(cfg, d) if compressed else 1.0
    c2 = privacy.c2_coefficient(
        cfg.local_lr, cfg.local_steps, cfg.clip * s, cfg.clients_per_round,
        cfg.num_clients, cfg.resolved_delta(),
        channels.effective_noise_std(cfg.channel))
    return jnp.minimum(jnp.float32(c2) * beta, jnp.float32(cfg.epsilon))


def _dp_epsilon_spend_dense(cfg: PFELSConfig, beta, d=None):
    """The spend for full-update (non-sparsifying) DP schemes (wfl_pdp):
    no compressor in the transmit path, so no sensitivity factor."""
    return _dp_epsilon_spend(cfg, beta, d, compressed=False)


def _pfels_support(cfg: PFELSConfig, d: int, k: int, prev_delta, key):
    """Sparsifying support omega_t — delegated to the configured
    ``repro.core.compressors`` registry entry (DESIGN.md §13). The
    paper's rand-k draw (incl. ``randk_mode="server_topk"``) lives in
    the ``rand_k`` entry, bit-exact with the pre-registry code."""
    comp = compressors.get_compressor(cfg.compressor)
    return comp.select_support(cfg, d, k, prev_delta, key)


def _full_support(cfg: PFELSConfig, d: int, k: int, prev_delta, key):
    """Full-update baselines transmit every coordinate (k = d)."""
    return compressors.Support(jnp.arange(d))


def _pfels_beta(cfg: PFELSConfig, gains, power_limits, d: int, k, *,
                epsilon=None, c1_scale: float = 1.0):
    """``gains`` are the channel model's EFFECTIVE observed gains (the
    design view of DESIGN.md §11); the privacy cap inside Theorem 5 uses
    the post-combining noise std for the same reason as the ledger.
    ``epsilon`` may be the schedule's traced per-round ceiling and ``k``
    a traced live-support count; ``c1_scale`` is the compressor's
    sensitivity factor — C1·s in the power cap keeps E||x_i||^2 <= P_i
    when the encoded signal's norm inflates, and in the privacy cap
    keeps beta <= eps/C2' consistent with the ledger's charge."""
    eps = cfg.epsilon if epsilon is None else epsilon
    return power_control.beta_pfels(
        gains, power_limits, d=d, k=k, c1=cfg.clip * c1_scale,
        eta=cfg.local_lr, tau=cfg.local_steps, epsilon=eps,
        r=cfg.clients_per_round, n=cfg.num_clients,
        delta=cfg.resolved_delta(),
        sigma0=channels.effective_noise_std(cfg.channel))


def _wfl_p_beta(cfg: PFELSConfig, gains, power_limits, d: int, k, *,
                epsilon=None, c1_scale: float = 1.0):
    return power_control.beta_wfl_p(
        gains, power_limits, c1=cfg.clip, eta=cfg.local_lr,
        tau=cfg.local_steps)


def _wfl_pdp_beta(cfg: PFELSConfig, gains, power_limits, d: int, k, *,
                  epsilon=None, c1_scale: float = 1.0):
    eps = cfg.epsilon if epsilon is None else epsilon
    return power_control.beta_wfl_pdp(
        gains, power_limits, c1=cfg.clip, eta=cfg.local_lr,
        tau=cfg.local_steps, epsilon=eps,
        r=cfg.clients_per_round, n=cfg.num_clients,
        delta=cfg.resolved_delta(),
        sigma0=channels.effective_noise_std(cfg.channel))


def _dp_fedavg_aggregate(cfg: PFELSConfig, flat_updates, noise_key, *,
                         d: int, r: int):
    return aggregation.dp_fedavg_aggregate(
        flat_updates, cfg.clip, cfg.dp_fedavg_sigma, noise_key, r=r)


def _dp_fedavg_spend(cfg: PFELSConfig, beta, d=None):
    """Per-round eps of the server-side Gaussian mechanism (Thm 1
    inverted). ``dp_fedavg_aggregate`` releases the clipped-update mean —
    client-level l2-sensitivity C/r — carrying noise std C*sigma/sqrt(r),
    i.e. noise multiplier z = sigma*sqrt(r), so
    eps = sqrt(2 ln(1.25/delta)) / z. Static config only (``beta`` plays
    no role in the digital baseline), hence a trace-safe constant.

    Found by replint RL301: the scheme injected DP noise every round but
    never charged the in-graph ledger, so reported budgets stayed (0, 0)
    — exactly the accounting drift arXiv 2304.04164 warns about."""
    z = cfg.dp_fedavg_sigma * math.sqrt(cfg.clients_per_round)
    eps = math.sqrt(2.0 * math.log(1.25 / cfg.resolved_delta())) / z
    # no cfg.epsilon cap here: unlike Thm 5 there is no design constraint
    # keeping this under budget, and a capped report would under-charge
    return jnp.float32(eps)


def _fedavg_aggregate(cfg: PFELSConfig, flat_updates, noise_key, *,
                      d: int, r: int):
    return aggregation.fedavg_aggregate(flat_updates)


register_algorithm("pfels", Algorithm(
    name="pfels", aircomp=True, select_support=_pfels_support,
    design_beta=_pfels_beta, privacy_spend=_dp_epsilon_spend,
    sparsifies_transmit=True))

register_algorithm("wfl_p", Algorithm(
    name="wfl_p", aircomp=True, select_support=_full_support,
    design_beta=_wfl_p_beta))

register_algorithm("wfl_pdp", Algorithm(
    name="wfl_pdp", aircomp=True, select_support=_full_support,
    design_beta=_wfl_pdp_beta, privacy_spend=_dp_epsilon_spend_dense))

register_algorithm("dp_fedavg", Algorithm(
    name="dp_fedavg", aircomp=False, server_aggregate=_dp_fedavg_aggregate,
    privacy_spend=_dp_fedavg_spend))

register_algorithm("fedavg", Algorithm(
    name="fedavg", aircomp=False, server_aggregate=_fedavg_aggregate))
