"""Client-side local training (Alg. 2 lines 6–12): tau steps of clipped SGD
(optionally with momentum, as in the paper's experiments §8.1)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.clipping import clip_by_global_norm
from repro.data.loader import sample_batch


def local_train(params, x, y, key, *, loss_fn: Callable, steps: int,
                lr: float, clip: float, momentum: float = 0.0,
                batch_size: int = 50):
    """Run tau local steps; returns (new_params, mean_loss).

    Assumption 1 (bounded gradient) is enforced by clipping each stochastic
    gradient to C1 before the SGD step [21].
    """
    v0 = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def step(carry, k):
        p, v = carry
        batch = sample_batch(k, x, y, batch_size)
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
        g, _ = clip_by_global_norm(g, clip)
        v = jax.tree.map(lambda v_, g_: momentum * v_
                         + g_.astype(jnp.float32), v, g)
        p = jax.tree.map(lambda p_, v_: (p_.astype(jnp.float32)
                                         - lr * v_).astype(p_.dtype), p, v)
        return (p, v), loss

    (p_new, _), losses = jax.lax.scan(step, (params, v0),
                                      jax.random.split(key, steps))
    return p_new, jnp.mean(losses)


def model_update(params_before, params_after):
    """Delta_i = theta_i^{t,tau} - theta^t (Alg. 2 line 11)."""
    return jax.tree.map(lambda a, b: (a.astype(jnp.float32)
                                      - b.astype(jnp.float32)),
                        params_after, params_before)
