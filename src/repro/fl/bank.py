"""ClientBank — population-scale per-client persistent state (DESIGN.md §10).

The paper samples r clients from a population of n per round (Alg. 2
line 2); everything the server must REMEMBER about individual clients
between their (rare) participations lives here, behind one interface:

  - the error-feedback residual memory ``e_i`` [28-30], ``(n, d)``;
  - the per-client PRNG lane keys (the round's ``ks[5]`` bank lane folded
    with the client id — the documented hook for client-local
    stochasticity such as dropout or local DP noise, DESIGN.md §5);
  - the per-client participation counts (Thm 2 subsampling bookkeeping).

Two backends share the ``ClientBank`` interface:

  - ``resident`` — dense device arrays, carried through ``lax.scan`` as
    part of ``TrainState``; bit-identical to the pre-bank behavior. The
    right choice while ``(n, d)`` fits device memory.
  - ``streamed`` — the bank stays host-side (numpy); only the sampled
    r-client cohort slice moves on/off device each round through the
    Trainer's donated gather/scatter buffers. Device memory is then
    independent of n (``benchmarks/population_scale.py`` trains
    n = 100_000), and the two backends are bit-identical at any n under
    the same key (``tests/test_bank.py``).

``BankState`` is the data (a registered pytree, so it checkpoints and
scan-carries); the backend objects are stateless policy — ``gather`` /
``scatter`` are traceable jnp ops for ``resident`` and in-place numpy for
``streamed`` (the Trainer clones the state at each ``run`` entry, so
caller-held states stay valid).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

BACKENDS = ("resident", "streamed")

# lane keys have the shape/dtype of a raw threefry key (jax >= 0.4.37
# floor; conftest pins x64 off)
_KEY_SHAPE = tuple(jax.random.PRNGKey(0).shape)
_KEY_DTYPE = jax.random.PRNGKey(0).dtype


@dataclass
class BankState:
    """All per-client persistent state, one registered pytree.

    ``residuals`` is ``None`` unless ``cfg.error_feedback``; ``lanes``
    holds each client's latest bank-lane key (zeros until first
    participation); ``counts`` is the participation tally. Leaves are
    device arrays under the ``resident`` backend and host numpy under
    ``streamed`` — the structure (and checkpoint layout) is identical.
    """
    residuals: Optional[Any]    # (n, d) f32 error-feedback memory or None
    lanes: Any                  # (n,) + key shape, per-client PRNG lanes
    counts: Any                 # (n,) i32 participation counts


jax.tree_util.register_dataclass(
    BankState, data_fields=["residuals", "lanes", "counts"], meta_fields=[])


def cohort_lane_keys(bank_key, sel):
    """The round's per-client bank lanes: ``fold_in(ks[5], client_id)``
    for each selected client — key identity is pinned by the lane
    contract test (DESIGN.md §5)."""
    return jax.vmap(lambda i: jax.random.fold_in(bank_key, i))(sel)


class ClientBank:
    """Backend interface. ``gather``/``scatter`` move the sampled cohort's
    slice of the bank; everything else in the round never touches
    ``(n, d)`` state."""

    backend: str

    def __init__(self, n: int, d: int, error_feedback: bool):
        self.n, self.d, self.error_feedback = n, d, error_feedback

    def init(self) -> BankState:
        raise NotImplementedError

    def gather(self, bank: BankState, sel):
        """-> (r, d) residual slice for the cohort, or None without EF."""
        raise NotImplementedError

    def scatter(self, bank: BankState, sel, new_residuals, lanes
                ) -> BankState:
        """Write back the cohort's updated residual slice + this round's
        lane keys, and bump the participation counts."""
        raise NotImplementedError

    def clone(self, bank: BankState) -> BankState:
        """A state safe to mutate without invalidating the caller's copy
        (no-op for functional backends)."""
        return bank


class ResidentBank(ClientBank):
    """Dense device-array backend — jnp gather/scatter, traceable inside
    jit/scan. Bit-identical to the pre-bank dense residual arrays."""

    backend = "resident"

    def init(self) -> BankState:
        return BankState(
            residuals=(jnp.zeros((self.n, self.d), jnp.float32)
                       if self.error_feedback else None),
            lanes=jnp.zeros((self.n,) + _KEY_SHAPE, _KEY_DTYPE),
            counts=jnp.zeros((self.n,), jnp.int32))

    def gather(self, bank: BankState, sel):
        if bank.residuals is None:
            return None
        return bank.residuals[sel]

    def scatter(self, bank: BankState, sel, new_residuals, lanes
                ) -> BankState:
        res = bank.residuals
        if res is not None and new_residuals is not None:
            res = res.at[sel].set(new_residuals)
        return BankState(residuals=res,
                         lanes=bank.lanes.at[sel].set(lanes),
                         counts=bank.counts.at[sel].add(1))


class StreamedBank(ClientBank):
    """Host-side numpy backend: the ``(n, d)`` residual bank never leaves
    host memory; ``gather`` hands out the (r, d) cohort slice (the Trainer
    device-puts it into a donated buffer) and ``scatter`` writes the
    updated slice back IN PLACE — callers own a ``clone`` per run."""

    backend = "streamed"

    def init(self) -> BankState:
        return BankState(
            residuals=(np.zeros((self.n, self.d), np.float32)
                       if self.error_feedback else None),
            lanes=np.zeros((self.n,) + _KEY_SHAPE, _KEY_DTYPE),
            counts=np.zeros((self.n,), np.int32))

    def gather(self, bank: BankState, sel):
        if bank.residuals is None:
            return None
        return bank.residuals[np.asarray(sel)]

    def scatter(self, bank: BankState, sel, new_residuals, lanes
                ) -> BankState:
        sel = np.asarray(sel)
        if bank.residuals is not None and new_residuals is not None:
            bank.residuals[sel] = np.asarray(new_residuals)
        bank.lanes[sel] = np.asarray(lanes)
        bank.counts[sel] += 1
        return bank

    def clone(self, bank: BankState) -> BankState:
        return BankState(
            residuals=(None if bank.residuals is None
                       else np.array(bank.residuals)),
            lanes=np.array(bank.lanes), counts=np.array(bank.counts))


def make_bank(backend: str, n: int, d: int, error_feedback: bool
              ) -> ClientBank:
    """Backend factory keyed by ``PFELSConfig.bank_backend``."""
    if backend == "resident":
        return ResidentBank(n, d, error_feedback)
    if backend == "streamed":
        return StreamedBank(n, d, error_feedback)
    raise ValueError(f"unknown bank backend {backend!r}; "
                     f"choose from {BACKENDS}")


def to_host(bank: BankState) -> BankState:
    """Device -> host copy (resident state into streamed layout)."""
    return BankState(
        residuals=(None if bank.residuals is None
                   else np.asarray(bank.residuals)),
        lanes=np.asarray(bank.lanes), counts=np.asarray(bank.counts))


def to_device(bank: BankState) -> BankState:
    """Host -> device copy (streamed state into resident layout)."""
    return BankState(
        residuals=(None if bank.residuals is None
                   else jnp.asarray(bank.residuals)),
        lanes=jnp.asarray(bank.lanes), counts=jnp.asarray(bank.counts))
