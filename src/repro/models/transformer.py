"""Model assembly: block-pattern stacks scanned over repeats.

A model is ``embed -> scan_{repeat}(pattern blocks) -> final_norm -> head``.
Patterns mix "attn" / "moe" / "mamba" blocks (DESIGN.md §4); whisper adds an
encoder stack + cross-attention; qwen2-vl consumes a stub vision prefix with
M-RoPE positions.

Three entry points per model: ``loss_fn`` (train), ``prefill`` and
``decode_step`` (serve). All are pure functions of (params, batch).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mamba2, moe as moe_lib
from repro.sharding.rules import constraint


# ------------------------------------------------------------------ compat

@jax.custom_vjp
def _opt_barrier(x):
    """``lax.optimization_barrier`` with the barrier-on-cotangents VJP the
    pinned jax (0.4.x) lacks — newer jax defines exactly this rule, so the
    shim keeps forward AND backward carries pinned against hoisting."""
    return jax.lax.optimization_barrier(x)


def _opt_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _opt_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


# ---------------------------------------------------------------- positions

def sinusoidal_pos(positions, d):
    """positions: (B, S) -> (B, S, d) float32 sinusoids."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def mrope_positions(cfg: ModelConfig, batch: int, seq: int):
    """(B, S, 3) t/h/w ids: a vision grid prefix then sequential text."""
    vp = cfg.vision_prefix
    grid_w = max(int(math.sqrt(max(vp, 1))), 1)
    i = jnp.arange(vp)
    vis = jnp.stack([jnp.zeros_like(i), i // grid_w, i % grid_w], axis=-1)
    start = (vp + grid_w - 1) // grid_w if vp else 0
    t = jnp.arange(seq - vp) + start
    txt = jnp.stack([t, t, t], axis=-1)
    pos = jnp.concatenate([vis, txt], axis=0).astype(jnp.int32)
    return jnp.broadcast_to(pos[None], (batch, seq, 3))


def text_positions(batch: int, seq: int, offset: int = 0):
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None] + offset,
                            (batch, seq))


# ------------------------------------------------------------------- blocks

def _block_init(key, kind: str, cfg: ModelConfig, *, cross: bool):
    ks = jax.random.split(key, 6)
    p, lg = {}, {}
    p["ln1"], lg["ln1"] = layers.norm_init(cfg.d_model, cfg.norm,
                                           jnp.dtype(cfg.param_dtype))
    if kind == "mamba":
        p["mamba"], lg["mamba"] = mamba2.mamba_init(ks[0], cfg)
        return p, lg
    p["attn"], lg["attn"] = attention.attn_init(ks[0], cfg)
    if cross:
        p["ln_cross"], lg["ln_cross"] = layers.norm_init(
            cfg.d_model, cfg.norm, jnp.dtype(cfg.param_dtype))
        p["cross"], lg["cross"] = attention.cross_attn_init(ks[1], cfg)
    p["ln2"], lg["ln2"] = layers.norm_init(cfg.d_model, cfg.norm,
                                           jnp.dtype(cfg.param_dtype))
    if kind == "moe":
        p["moe"], lg["moe"] = moe_lib.moe_init(
            ks[2], cfg, experts_padded=cfg.moe.experts_padded(_model_axis()))
    else:
        p["mlp"], lg["mlp"] = layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                              cfg.mlp_act,
                                              jnp.dtype(cfg.param_dtype))
    return p, lg


def _model_axis() -> int:
    from repro.sharding.rules import get_abstract_mesh_or_none
    m = get_abstract_mesh_or_none()
    return m.shape.get("model", 1) if m is not None else 1


def _block_apply(p, kind: str, cfg: ModelConfig, x, positions, *, mode: str,
                 cache=None, window=None, enc_kv=None, causal=True):
    """Returns (x, new_cache, aux)."""
    aux = {}
    h = layers.norm_apply(p["ln1"], x, cfg.norm, impl=cfg.norm_impl)
    if kind == "mamba":
        if mode == "decode":
            y, new_cache = mamba2.mamba_decode(p["mamba"], cfg, h, cache)
        else:
            y, new_cache = mamba2.mamba_train(p["mamba"], cfg, h)
        return x + y, new_cache, aux
    if mode == "decode":
        y, new_cache = attention.attn_decode(p["attn"], cfg, h, cache,
                                             window=window,
                                             positions=positions)
    else:
        if causal:
            y, kv = attention.attn_train(p["attn"], cfg, h, positions,
                                         window=window)
        else:  # encoder: bidirectional
            q_pos = positions if positions.ndim == 2 else positions[..., 0]
            qkv = attention._project(p["attn"], cfg, h, positions)
            out = attention.flash_attention(
                qkv[0], qkv[1], qkv[2], q_pos, q_pos, causal=False,
                window=None)
            b, s = out.shape[:2]
            y = out.reshape(b, s, -1) @ p["attn"]["wo"].astype(h.dtype)
            kv = None
        new_cache = kv
    x = x + y
    if enc_kv is not None:
        hc = layers.norm_apply(p["ln_cross"], x, cfg.norm, impl=cfg.norm_impl)
        x = x + attention.cross_attn_apply(p["cross"], cfg, hc, enc_kv)
    h2 = layers.norm_apply(p["ln2"], x, cfg.norm, impl=cfg.norm_impl)
    if kind == "moe":
        y2, moe_aux = moe_lib.moe_apply(p["moe"], cfg, h2)
        aux.update(moe_aux)
    else:
        y2 = layers.mlp_apply(p["mlp"], h2, cfg.mlp_act)
    return x + y2, new_cache, aux


# -------------------------------------------------------------------- init

def init_params(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    """Returns (params, logical) pytrees. Stacked block params have a leading
    repeat ('layers') dim."""
    rep = cfg.resolved_repeat()
    pat = cfg.block_pattern
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    vpad = layers.pad_vocab(cfg.vocab_size)

    params: Dict[str, Any] = {}
    logical: Dict[str, Any] = {}
    params["embed"], logical["embed"] = layers.embed_init(keys[0], vpad,
                                                          cfg.d_model, dtype)

    def stack_init(key, kind, cross=False):
        ks = jax.random.split(key, rep)
        per = [_block_init(k, kind, cfg, cross=cross) for k in ks]
        p = jax.tree.map(lambda *xs: jnp.stack(xs), *[pp for pp, _ in per])
        lg = jax.tree.map(lambda ax: ("layers",) + tuple(ax), per[0][1],
                          is_leaf=lambda t: isinstance(t, tuple))
        return p, lg

    blocks, blocks_lg = [], []
    bkeys = jax.random.split(keys[1], len(pat))
    for i, kind in enumerate(pat):
        p, lg = stack_init(bkeys[i], kind, cross=cfg.is_encoder_decoder
                           and kind != "mamba")
        blocks.append(p)
        blocks_lg.append(lg)
    params["blocks"] = tuple(blocks)
    logical["blocks"] = tuple(blocks_lg)

    params["final_norm"], logical["final_norm"] = layers.norm_init(
        cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": layers._normal(keys[2], (cfg.d_model, vpad),
                                1 / math.sqrt(cfg.d_model), dtype)}
        logical["lm_head"] = {"w": ("fsdp", "tensor")}

    if cfg.is_encoder_decoder:
        erep = cfg.n_encoder_layers
        ekeys = jax.random.split(keys[3], erep)
        per = [_block_init(k, "attn", cfg, cross=False) for k in ekeys]
        params["enc_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                            *[pp for pp, _ in per])
        logical["enc_blocks"] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), per[0][1],
            is_leaf=lambda t: isinstance(t, tuple))
        params["enc_final_norm"], logical["enc_final_norm"] = \
            layers.norm_init(cfg.d_model, cfg.norm, dtype)
    return params, logical


def init_shapes(cfg: ModelConfig):
    """Shape-only init (no allocation) for the dry-run."""
    return jax.eval_shape(lambda k: init_params(k, cfg)[0],
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def logical_axes(cfg: ModelConfig):
    """Logical tree without materialising params (the logical tree is pure
    python, captured as a side-effect of an abstract trace)."""
    box = {}

    def f(k):
        p, lg = init_params(k, cfg)
        box["lg"] = lg
        return p

    jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return box["lg"]


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ----------------------------------------------------------------- encoder

def encode(params, cfg: ModelConfig, audio_embeds):
    """Whisper encoder over stub frame embeddings (B, Senc, D)."""
    b, s, _ = audio_embeds.shape
    pos = text_positions(b, s)
    x = audio_embeds + sinusoidal_pos(pos, cfg.d_model).astype(
        audio_embeds.dtype)

    def body(x, blk):
        x, _, _ = _block_apply(blk, "attn", cfg, x, pos, mode="train",
                               causal=False)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.norm_apply(params["enc_final_norm"], x, cfg.norm, impl=cfg.norm_impl)


# ------------------------------------------------------------------ embed+

def _embed_inputs(params, cfg: ModelConfig, tokens, extra_embeds):
    """tokens: (B, S_text); extra_embeds: vision/audio prefix or None.
    Returns (x, positions)."""
    x = layers.embed_apply(params["embed"], tokens)
    b = tokens.shape[0]
    if cfg.family == "vlm" and extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        pos = mrope_positions(cfg, b, x.shape[1])
    else:
        pos = text_positions(b, x.shape[1])
        if cfg.rope_theta <= 0:   # whisper: sinusoidal absolute
            x = x + sinusoidal_pos(pos, cfg.d_model).astype(x.dtype)
    x = constraint(x, "batch", None, None)
    return x, pos


# ------------------------------------------------------------------- train

def forward_train(params, cfg: ModelConfig, batch, *, remat: bool = True,
                  window=None):
    """batch: {tokens, labels, [vision_embeds|audio_embeds]}.
    Returns (loss, metrics)."""
    tokens = batch["tokens"]
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["audio_embeds"])
    x, pos = _embed_inputs(params, cfg, tokens,
                           batch.get("vision_embeds"))
    pat = cfg.block_pattern

    def body(x, blk):
        # carry saved by remat: shard d_model over `model` to keep the
        # per-layer checkpoint small (all-gathered on first use inside).
        # The barrier stops XLA hoisting a whole-stack f32 convert of the
        # saved carries out of the backward loop (a 2x memory pessimisation
        # observed on the CPU backend).
        x = _opt_barrier(x)
        x = constraint(x, "batch", None, "tensor")
        aux_sum = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pat):
            enc_kv = None
            if cfg.is_encoder_decoder and kind != "mamba":
                enc_kv = attention.encode_cross_kv(blk[i]["cross"], cfg,
                                                   enc_out)
            x, _, aux = _block_apply(blk[i], kind, cfg, x, pos, mode="train",
                                     window=window, enc_kv=enc_kv)
            if "load_balance_loss" in aux:
                aux_sum = aux_sum + aux["load_balance_loss"]
        return x, aux_sum

    if remat:
        body = jax.checkpoint(body)
    x, aux = jax.lax.scan(body, x, params["blocks"])
    x = layers.norm_apply(params["final_norm"], x, cfg.norm, impl=cfg.norm_impl)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    labels = batch["labels"]
    if cfg.family == "vlm":  # no loss on the vision prefix
        prefix = x.shape[1] - labels.shape[1]
        x = x[:, prefix:]
    vpad = layers.pad_vocab(cfg.vocab_size)
    if x.shape[1] * vpad > 2 ** 26:  # large S*V: stream the loss
        loss = layers.chunked_cross_entropy(x, head, labels, cfg.vocab_size,
                                            tied=cfg.tie_embeddings)
    else:
        logits = layers.logits_apply(head, x, tied=cfg.tie_embeddings)
        loss = layers.cross_entropy(logits, labels, cfg.vocab_size)
    metrics = {"loss": loss, "aux_loss": jnp.mean(aux)}
    total = loss + 0.01 * jnp.mean(aux)
    return total, metrics


# ----------------------------------------------------------------- serving

def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                      window, dtype, enc_out=None, blk=None):
    if kind == "mamba":
        return mamba2.make_mamba_cache(cfg, batch, dtype)
    return attention.make_decode_cache(cfg, batch, cache_len, window=window,
                                       dtype=dtype)


def prefill(params, cfg: ModelConfig, batch, *, window=None,
            extra_slots: int = 0):
    """Full forward over the prompt; returns (last_logits, caches, enc_out).
    ``extra_slots`` reserves KV-cache room for subsequent decode steps."""
    tokens = batch["tokens"]
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["audio_embeds"])
    x, pos = _embed_inputs(params, cfg, tokens, batch.get("vision_embeds"))
    pat = cfg.block_pattern

    def body(x, blk):
        caches = []
        for i, kind in enumerate(pat):
            enc_kv = None
            if cfg.is_encoder_decoder and kind != "mamba":
                enc_kv = attention.encode_cross_kv(blk[i]["cross"], cfg,
                                                   enc_out)
            x, c, _ = _block_apply(blk[i], kind, cfg, x, pos, mode="prefill",
                                   window=window, enc_kv=enc_kv)
            if kind != "mamba":
                k, v = c["k"], c["v"]
                if extra_slots:
                    padw = [(0, 0), (0, extra_slots), (0, 0), (0, 0)]
                    k, v = jnp.pad(k, padw), jnp.pad(v, padw)
                c = {"k": k, "v": v,
                     "idx": jnp.array(x.shape[1], jnp.int32),
                     "slot_pos": jnp.arange(k.shape[1], dtype=jnp.int32)}
            caches.append(c)
        return x, tuple(caches)

    x, caches = jax.lax.scan(body, x, params["blocks"])
    x = layers.norm_apply(params["final_norm"], x, cfg.norm, impl=cfg.norm_impl)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.logits_apply(head, x[:, -1:], tied=cfg.tie_embeddings)
    return logits, caches, enc_out


def make_caches(cfg: ModelConfig, batch: int, cache_len: int, *, window=None,
                dtype=jnp.bfloat16):
    """Empty stacked caches for `serve_step` input specs: pytree matching the
    scan layout (leading repeat dim per pattern element)."""
    rep = cfg.resolved_repeat()
    caches = []
    for kind in cfg.block_pattern:
        one = _init_block_cache(cfg, kind, batch, cache_len, window, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (rep,) + x.shape), one))
    return tuple(caches)


def decode_step(params, cfg: ModelConfig, token, caches, *, window=None,
                enc_out=None):
    """token: (B, 1) -> (logits (B,1,V), new caches). The per-layer caches
    are scan xs/ys so the stacked layout is preserved."""
    x = layers.embed_apply(params["embed"], token)
    b = token.shape[0]
    if cfg.rope_theta <= 0 and "idx" in caches[0]:
        idx = caches[0]["idx"][0]
        pos = jnp.broadcast_to(jnp.reshape(idx, (1, 1)), (b, 1))
        x = x + sinusoidal_pos(pos, cfg.d_model).astype(x.dtype)
    x = constraint(x, "batch", None, None)
    pat = cfg.block_pattern

    # M-RoPE decode positions: a text token at absolute index i sits at
    # rotary position start + (i - vision_prefix) on all three streams
    dec_pos = None
    if cfg.mrope and cfg.vision_prefix:
        idx0 = None
        for c0 in caches:
            if isinstance(c0, dict) and "idx" in c0:
                idx0 = c0["idx"][0]
                break
        if idx0 is not None:
            grid_w = max(int(math.sqrt(max(cfg.vision_prefix, 1))), 1)
            start = (cfg.vision_prefix + grid_w - 1) // grid_w
            p1 = (idx0 - cfg.vision_prefix + start).astype(jnp.int32)
            dec_pos = jnp.broadcast_to(p1.reshape(1, 1, 1), (b, 1, 3))

    def body(x, xs):
        blk, caches_l = xs
        new = []
        for i, kind in enumerate(pat):
            enc_kv = None
            if cfg.is_encoder_decoder and kind != "mamba" and enc_out is not None:
                enc_kv = attention.encode_cross_kv(blk[i]["cross"], cfg,
                                                   enc_out)
            x, c, _ = _block_apply(blk[i], kind, cfg, x, dec_pos,
                                   mode="decode", cache=caches_l[i],
                                   window=window, enc_kv=enc_kv)
            new.append(c)
        return x, tuple(new)

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    x = layers.norm_apply(params["final_norm"], x, cfg.norm, impl=cfg.norm_impl)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.logits_apply(head, x, tied=cfg.tie_embeddings)
    return logits, new_caches
