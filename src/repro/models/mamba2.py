"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like) term + inter-chunk state recurrence via lax.scan over
chunks. Decode is the O(1) recurrent update. The chunk computation itself is
the perf hot-spot and has a Pallas kernel (repro.kernels.ssd_scan) whose
oracle is the same math as here.

Per head: h_t = a_t * h_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t h_t
with a_t = exp(dt_t * A) (A < 0 scalar per head), B_t, C_t in R^N,
x_t in R^P.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.rules import constraint


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    nheads = ssm.num_heads or d_inner // ssm.head_dim
    return d_inner, nheads, ssm.head_dim, ssm.state_dim


def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ssm = cfg.ssm
    d_inner, nh, p_dim, n = _dims(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    conv_dim = d_inner + 2 * n          # conv over x, B, C streams
    params = {
        # in_proj -> [z (d_inner), x (d_inner), B (n), C (n), dt (nh)]
        "in_proj": layers._normal(ks[0], (d, 2 * d_inner + 2 * n + nh),
                                  1 / math.sqrt(d), dtype),
        "conv_w": layers._normal(ks[1], (ssm.conv_width, conv_dim),
                                 1 / math.sqrt(ssm.conv_width), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": layers._normal(ks[2], (d_inner, d),
                                   1 / math.sqrt(d_inner), dtype),
    }
    logical = {
        "in_proj": ("fsdp", "tensor"), "conv_w": (None, "tensor"),
        "conv_b": ("tensor",), "A_log": (None,), "D": (None,),
        "dt_bias": (None,), "norm_scale": ("tensor",),
        "out_proj": ("tensor", "fsdp"),
    }
    return params, logical


def _split_proj(cfg, proj):
    d_inner, nh, p_dim, n = _dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, width W. xbc: (B,S,C). If conv_state (B,W-1,C)
    is given (decode), prepend it; returns (out, new_state)."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (w - 1,) + xbc.shape[2:], xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)
    else:
        xp = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
              for i in range(w))
    out = jax.nn.silu(out + conv_b.astype(xbc.dtype))
    new_state = xp[:, -(w - 1):] if w > 1 else None
    return out, new_state


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan (pure jnp; oracle for the Pallas kernel).

    x: (b, s, h, p) values; dt: (b, s, h) positive step sizes;
    A: (h,) negative decay rates; B, C: (b, s, n) shared across heads
    (Mamba2 uses one B/C group); returns y: (b, s, h, p), final state
    (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)

    # log decay per step: la[t] = dt[t] * A  (A < 0)
    la = dtc * A[None, None, None, :]            # (b,nc,chunk,h)
    cum = jnp.cumsum(la, axis=2)                 # inclusive cumsum
    # intra-chunk: y[i] += sum_{j<=i} exp(cum[i]-cum[j]) * (C_i.B_j) dt_j x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,i,j,h)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    decay = jnp.exp(jnp.where(causal, seg, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)   # (b,nc,i,j)
    w = cb[..., None] * decay                    # (b,nc,i,j,h)
    xdt = xc * dtc[..., None]                    # (b,nc,chunk,h,p)
    y = jnp.einsum("bcijh,bcjhp->bcihp", w, xdt)

    # chunk-final states: S_c = sum_j exp(cum[last]-cum[j]) B_j (dt_j x_j)^T
    dec_last = jnp.exp(cum[:, :, -1:, :] - cum)   # (b,nc,chunk,h)
    state_c = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, dec_last, xdt)

    # inter-chunk recurrence: H_c = exp(sum la_c) H_{c-1} + S_c
    chunk_decay = jnp.exp(cum[:, :, -1, :])       # (b,nc,h)

    def scan_body(hprev, xs):
        s_c, d_c = xs
        hnew = hprev * d_c[..., None, None] + s_c
        return hnew, hprev

    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))
    hfinal, hprevs = jax.lax.scan(
        scan_body, h0,
        (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)           # (b,nc,h,p,n)

    # carried-in contribution: y[i] += C_i · (exp(cum[i]) * H_{c-1})
    y = y + jnp.einsum("bcin,bcih,bchpn->bcihp",
                       Cc, jnp.exp(cum), hprevs)
    return y.reshape(b, s, h, p), hfinal


def ssd_decode_step(x, dt, A, B, C, state):
    """One-step recurrence. x: (b,h,p); dt: (b,h); B,C: (b,n);
    state: (b,h,p,n) -> (y (b,h,p), new state)."""
    a = jnp.exp(dt.astype(jnp.float32) * A[None, :])          # (b,h)
    xdt = x.astype(jnp.float32) * dt[..., None]
    upd = jnp.einsum("bhp,bn->bhpn", xdt, B.astype(jnp.float32))
    new_state = state * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
    return y, new_state


def _gated_out(params, cfg, y, z, x_resid_D, dt=None):
    d_inner, nh, p_dim, n = _dims(cfg)
    y = y + params["D"][None, None, :, None] * x_resid_D
    y = y.reshape(y.shape[0], y.shape[1], d_inner)
    y = y.astype(z.dtype) * jax.nn.silu(z)
    y = layers.norm_apply({"scale": params["norm_scale"]}, y, "rmsnorm")
    return y @ params["out_proj"].astype(y.dtype)


def mamba_train(params, cfg: ModelConfig, x_in, use_kernel: bool = False):
    """x_in: (B,S,D) -> (B,S,D); also returns final SSD+conv state (prefill)."""
    d_inner, nh, p_dim, n = _dims(cfg)
    proj = x_in @ params["in_proj"].astype(x_in.dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    b, s = xs.shape[:2]
    xh = xs.reshape(b, s, nh, p_dim)
    xh = constraint(xh, "batch", None, "tensor", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    dt = jnp.clip(dt, cfg.ssm.dt_min, None)
    A = -jnp.exp(params["A_log"])
    chunk = min(cfg.ssm.chunk_size, s)
    while chunk > 1 and s % chunk != 0:   # chunk must divide the seq len
        chunk //= 2
    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, state = ssd_ops.ssd_scan(xh, dt, A, B, C, chunk=chunk)
    else:
        y, state = ssd_chunked(xh, dt, A, B, C, chunk)
    out = _gated_out(params, cfg, y.astype(x_in.dtype), z,
                     xh.astype(jnp.float32))
    return out, {"ssm": state.astype(jnp.float32),
                 "conv": conv_state.astype(x_in.dtype)}


def make_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    d_inner, nh, p_dim, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return {"ssm": jnp.zeros((batch, nh, p_dim, n), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_dim),
                              dtype)}


def mamba_decode(params, cfg: ModelConfig, x_in, cache):
    """x_in: (B,1,D); cache = {ssm (B,H,P,N), conv (B,W-1,C)}."""
    d_inner, nh, p_dim, n = _dims(cfg)
    proj = x_in @ params["in_proj"].astype(x_in.dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   conv_state=cache["conv"])
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    b = xs.shape[0]
    xh = xs.reshape(b, nh, p_dim)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"][None, :])
    dt = jnp.clip(dt, cfg.ssm.dt_min, None)
    A = -jnp.exp(params["A_log"])
    y, new_ssm = ssd_decode_step(xh, dt, A, B[:, 0], C[:, 0], cache["ssm"])
    out = _gated_out(params, cfg, y[:, None].astype(x_in.dtype), z,
                     xh[:, None].astype(jnp.float32))
    return out, {"ssm": new_ssm, "conv": conv_state}
