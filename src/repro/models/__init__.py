from repro.models import attention, cnn, layers, mamba2, moe, transformer
from repro.models.transformer import (decode_step, forward_train, init_params,
                                      init_shapes, logical_axes, make_caches,
                                      param_count, prefill)

__all__ = [
    "attention", "cnn", "layers", "mamba2", "moe", "transformer",
    "decode_step", "forward_train", "init_params", "init_shapes",
    "logical_axes", "make_caches", "param_count", "prefill",
]
