"""Attention: GQA with RoPE / M-RoPE, blockwise-flash softmax (scan over KV
blocks with online max/denominator — keeps the (Sq x Skv) score matrix out of
memory for 32k prefill), sliding-window variant, and decode with a
(ring-buffer) KV cache.

Sharding: heads are sharded over `model` when divisible; otherwise the query
sequence dim is sharded over `model` (context parallelism) — decided at trace
time against the ambient mesh.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.rules import constraint, get_abstract_mesh_or_none

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ------------------------------------------------------------------- params

def attn_init(key, cfg: ModelConfig, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": layers._normal(ks[0], (d, h * hd), s, dtype),
        "wk": layers._normal(ks[1], (d, hkv * hd), s, dtype),
        "wv": layers._normal(ks[2], (d, hkv * hd), s, dtype),
        "wo": layers._normal(ks[3], (h * hd, d), 1.0 / math.sqrt(h * hd), dtype),
    }
    lg = {"wq": ("fsdp", "tensor"), "wk": ("fsdp", "tensor"),
          "wv": ("fsdp", "tensor"), "wo": ("tensor", "fsdp")}
    if cfg.qkv_bias:
        p.update({"bq": jnp.zeros((h * hd,), dtype),
                  "bk": jnp.zeros((hkv * hd,), dtype),
                  "bv": jnp.zeros((hkv * hd,), dtype)})
        lg.update({"bq": ("tensor",), "bk": ("tensor",), "bv": ("tensor",)})
    return p, lg


# ------------------------------------------------------------ shard helpers

def _heads_divisible(h: int) -> bool:
    mesh = get_abstract_mesh_or_none()
    if mesh is None:
        return True
    return h % mesh.shape.get("model", 1) == 0


def shard_qkv(x, h: int):
    """(B,S,H,Dh): heads over model if divisible, else seq over model."""
    if _heads_divisible(h):
        return constraint(x, "batch", None, "tensor", None)
    return constraint(x, "batch", "seq_mp", None, None)


def kv_cache_spec(shape, mesh):
    """Spec for a (B, S, Hkv, Dh) decode cache: batch over (pod,data) when
    divisible, and the HEAD DIM over `model`. Sharding Dh (rather than S)
    keeps the dynamic-slot token write local — a seq-sharded cache forces
    GSPMD to all-gather the whole cache around the dynamic-update-slice
    (measured +15 GiB/device on 32k decode). Dh of every assigned arch
    (64/80/96/128/160) divides the 16-way model axis."""
    from repro.sharding.rules import _usable_axes
    usable = _usable_axes(mesh)
    b, hkv, dh = shape[0], shape[2], shape[3]
    batch_axes = tuple(a for a in ("pod", "data") if a in usable)
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    if not batch_axes or b % bsz != 0:
        batch_axes = None
    msize = mesh.shape.get("model", 1)
    if "model" in usable and dh % msize == 0:
        return (batch_axes, None, None, "model")
    if "model" in usable and hkv % msize == 0:
        return (batch_axes, None, "model", None)
    return (batch_axes, None, None, None)


def shard_cache(x):
    mesh = get_abstract_mesh_or_none()
    if mesh is None or x.ndim != 4:
        return x
    from jax.sharding import PartitionSpec as P
    spec = kv_cache_spec(x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ------------------------------------------------------- blockwise attention

def flash_attention(q, k, v, pos_q, pos_kv, *, causal: bool,
                    window: Optional[int], kv_valid=None,
                    block_kv: int = 512, remat: bool = True):
    """Online-softmax attention.

    q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh); pos_q: (B, Sq); pos_kv:
    (B, Skv) absolute positions (ring buffers pass slot positions).
    kv_valid: optional (B, Skv) bool. Returns (B, Sq, H, Dh).
    """
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(dh)
    # keep matmul operands in the input dtype and accumulate in f32
    # (preferred_element_type); casting K/V to f32 here would let XLA hoist
    # a whole-cache f32 convert out of the KV loop (+6 GiB on 32k decode)
    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qf = qf.reshape(b, sq, hkv, g, dh)

    if skv % block_kv != 0:
        block_kv = skv
    nblk = skv // block_kv

    kb = k.reshape(b, nblk, block_kv, hkv, dh)
    vb = v.reshape(b, nblk, block_kv, hkv, dh)
    pb = pos_kv.reshape(b, nblk, block_kv)
    valid_b = (kv_valid.reshape(b, nblk, block_kv)
               if kv_valid is not None else None)

    def body(carry, xs):
        m, l, acc = carry
        if valid_b is not None:
            kc, vc, pc, vld = xs
        else:
            kc, vc, pc = xs
            vld = None
        # scores: (B, Sq, Hkv, G, block_kv), f32 accumulation
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kc,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((b, sq, block_kv), bool)
        if causal:
            mask &= pos_q[:, :, None] >= pc[:, None, :]
        if window is not None:
            mask &= pos_q[:, :, None] - pc[:, None, :] < window
        if vld is not None:
            mask &= vld[:, None, :]
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, dh), jnp.float32)
    xs = (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
          jnp.moveaxis(pb, 1, 0))
    if valid_b is not None:
        xs = xs + (jnp.moveaxis(valid_b, 1, 0),)
    # checkpoint per KV block: the backward recomputes the block's scores
    # instead of saving the (B,Sq,H,block) probability tensors (flash-bwd);
    # skipped in decode (no grad) where it only bloats the loop state
    body_fn = jax.checkpoint(body) if remat else body
    (m, l, acc), _ = jax.lax.scan(body_fn, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, dh).astype(q.dtype)


# --------------------------------------------------------------- full apply

def _project(p, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q, k, v = (q + p["bq"].astype(x.dtype), k + p["bk"].astype(x.dtype),
                   v + p["bv"].astype(x.dtype))
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.mrope and positions.ndim == 3:
        q = layers.apply_mrope(q, positions, cfg.rope_theta)
        k = layers.apply_mrope(k, positions, cfg.rope_theta)
    else:
        pos1 = positions if positions.ndim == 2 else positions[..., 0]
        q = layers.apply_rope(q, pos1, cfg.rope_theta)
        k = layers.apply_rope(k, pos1, cfg.rope_theta)
    q = shard_qkv(q, h)
    k = shard_qkv(k, hkv)
    v = shard_qkv(v, hkv)
    return q, k, v


def attn_train(p, cfg: ModelConfig, x, positions, *, window=None,
               block_kv: Optional[int] = None):
    """Full causal (optionally windowed) self-attention for train/prefill."""
    q, k, v = _project(p, cfg, x, positions)
    pos1 = positions if positions.ndim == 2 else positions[..., 0]
    out = flash_attention(q, k, v, pos1, pos1, causal=True,
                          window=window or cfg.sliding_window,
                          block_kv=block_kv or cfg.attn_block_kv)
    b, s, _, _ = out.shape
    y = out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)
    return y, {"k": shard_cache(k), "v": shard_cache(v)}


def make_decode_cache(cfg: ModelConfig, batch: int, cache_len: int, *,
                      window: Optional[int], dtype):
    """Cache layout: full mode stores `cache_len` slots; sliding-window mode
    stores `window` slots as a ring buffer. `idx` = number of tokens already
    in context; `slot_pos` = absolute position stored in each ring slot."""
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
    slots = min(window, cache_len) if window else cache_len
    return {
        "k": jnp.zeros((batch, slots, hkv, hd), dtype),
        "v": jnp.zeros((batch, slots, hkv, hd), dtype),
        "idx": jnp.zeros((), jnp.int32),
        "slot_pos": jnp.zeros((slots,), jnp.int32),
    }


def attn_decode(p, cfg: ModelConfig, x, cache, *, window: Optional[int],
                block_kv: Optional[int] = None, positions=None):
    """One-token decode. x: (B, 1, D). Writes this token's K/V into the cache
    (ring-buffer write in sliding-window mode) and attends over valid slots."""
    b = x.shape[0]
    idx = cache["idx"]
    slots = cache["k"].shape[1]
    if positions is None:
        pos = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
        if cfg.mrope:
            pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))
    else:
        pos = positions
    q, k_new, v_new = _project(p, cfg, x, pos)
    if window is None:
        slot = jnp.minimum(idx, slots - 1).astype(jnp.int32)
    else:
        slot = (idx % slots).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    k, v = shard_cache(k), shard_cache(v)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], idx[None], slot, axis=0)
    pos_kv = jnp.broadcast_to(slot_pos[None, :], (b, slots))
    valid = pos_kv <= idx
    if window is not None:
        valid &= pos_kv > idx - window
    pos_q = jnp.broadcast_to(idx[None, None], (b, 1))
    out = flash_attention(q, k, v, pos_q, pos_kv, causal=True, window=window,
                          kv_valid=valid,
                          block_kv=block_kv or 2 * cfg.attn_block_kv,
                          remat=False)
    y = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    new_cache = {"k": k, "v": v, "idx": idx + 1, "slot_pos": slot_pos}
    return y, new_cache


# ------------------------------------------------------------ cross-attention

def cross_attn_init(key, cfg: ModelConfig):
    return attn_init(key, cfg)


def cross_attn_apply(p, cfg: ModelConfig, x, enc_kv):
    """enc_kv: dict with precomputed encoder k, v (B, Senc, Hkv, Dh)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim()
    q = (x @ p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    senc = enc_kv["k"].shape[1]
    pos_q = jnp.zeros((b, s), jnp.int32)
    pos_kv = jnp.zeros((b, senc), jnp.int32)
    out = flash_attention(q, enc_kv["k"], enc_kv["v"], pos_q, pos_kv,
                          causal=False, window=None)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def encode_cross_kv(p, cfg: ModelConfig, enc_out):
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
    k = (enc_out @ p["wk"].astype(enc_out.dtype))
    v = (enc_out @ p["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return {"k": k.reshape(b, s, hkv, hd), "v": v.reshape(b, s, hkv, hd)}
