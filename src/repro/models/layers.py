"""Shared model layers: norms, RoPE / M-RoPE, MLPs, initializers.

All functions are pure; params are plain dict pytrees. Each init function
returns ``(params, logical)`` where ``logical`` mirrors params with tuples of
logical axis names (resolved by repro.sharding.rules).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding.rules import constraint


# ---------------------------------------------------------------- initializers

def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool, dtype,
               logical=("fsdp", "tensor")):
    kw, kb = jax.random.split(key)
    p = {"w": _normal(kw, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)}
    lg = {"w": logical}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        lg["b"] = (logical[1],)
    return p, lg


def dense_apply(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ----------------------------------------------------------------------- norms

def norm_init(d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}, {"scale": (None,)}
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": (None,), "bias": (None,)})


def norm_apply(p, x, kind: str, eps: float = 1e-6, impl: str = "f32"):
    if impl == "stats_f32":
        # statistics in f32, scaling in the input dtype: the activation
        # cotangent stays bf16 (halves the backward all-reduce bytes)
        xf = x.astype(jnp.float32)
        if kind == "rmsnorm":
            ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
            r = jax.lax.rsqrt(ms + eps).astype(x.dtype)
            y = x * r * p["scale"].astype(x.dtype)
        else:
            mu = jnp.mean(xf, axis=-1, keepdims=True)
            var = jnp.var(xf, axis=-1, keepdims=True)
            r = jax.lax.rsqrt(var + eps).astype(x.dtype)
            y = (x - mu.astype(x.dtype)) * r * p["scale"].astype(x.dtype)
        if "bias" in p:
            y = y + p["bias"].astype(x.dtype)
        return y
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------------------ RoPE

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) int32. Standard 1-D RoPE."""
    if theta <= 0:
        return x
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float
                ) -> jnp.ndarray:
    """M-RoPE (Qwen2-VL): positions3 (B, S, 3) = (t, h, w) ids; the rotary
    spectrum is split into 3 sections, one per position stream."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = rope_freqs(dh, theta)                       # (half,)
    # section sizes ~ (2/8, 3/8, 3/8) of the spectrum, Qwen2-VL style
    s_t = half // 4
    s_h = (half - s_t) // 2
    s_w = half - s_t - s_h
    sect = jnp.concatenate([jnp.zeros((s_t,), jnp.int32),
                            jnp.ones((s_h,), jnp.int32),
                            2 * jnp.ones((s_w,), jnp.int32)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                 # (B,S,3)
        jnp.broadcast_to(sect[None, None, :],
                         positions3.shape[:2] + (half,)),
        axis=-1)                                        # (B,S,half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------ MLPs

def mlp_init(key, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        p = {"wi": _normal(ks[0], (d_model, d_ff), 1 / math.sqrt(d_model), dtype),
             "wg": _normal(ks[1], (d_model, d_ff), 1 / math.sqrt(d_model), dtype),
             "wo": _normal(ks[2], (d_ff, d_model), 1 / math.sqrt(d_ff), dtype)}
        lg = {"wi": ("fsdp", "tensor"), "wg": ("fsdp", "tensor"),
              "wo": ("tensor", "fsdp")}
    else:
        p = {"wi": _normal(ks[0], (d_model, d_ff), 1 / math.sqrt(d_model), dtype),
             "wo": _normal(ks[2], (d_ff, d_model), 1 / math.sqrt(d_ff), dtype)}
        lg = {"wi": ("fsdp", "tensor"), "wo": ("tensor", "fsdp")}
    return p, lg


def mlp_apply(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(x.dtype))
    h = constraint(h, "batch", None, "tensor")
    return h @ p["wo"].astype(x.dtype)


# ----------------------------------------------------------- embedding / head

def embed_init(key, vocab_padded: int, d_model: int, dtype):
    p = {"table": _normal(key, (vocab_padded, d_model), 1.0, dtype)}
    return p, {"table": ("tensor", "fsdp")}


@jax.custom_vjp
def _embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


def _embed_lookup_fwd(table, tokens):
    return jnp.take(table, tokens, axis=0), (tokens, table)


def _embed_lookup_bwd(res, ct):
    """Embedding gradient via a scatter-add whose operand is sharded ONLY
    over the d_model (window) dim — GSPMD partitions window dims of scatters
    without index masking, avoiding a replicated (V, d) f32 buffer; the
    result is then resharded to the param sharding by the consumer."""
    tokens, table = res
    shape, dtype = table.shape, table.dtype
    d = shape[1]
    g = jnp.zeros(shape, jnp.float32)
    g = constraint(g, None, "seq_all")      # d over (data, model)
    g = g.at[tokens.reshape(-1)].add(
        ct.reshape(-1, d).astype(jnp.float32))
    g = constraint(g, None, "seq_all")
    return g.astype(dtype), None


_embed_lookup.defvjp(_embed_lookup_fwd, _embed_lookup_bwd)


def embed_apply(p, tokens):
    return _embed_lookup(p["table"], tokens)


def logits_apply(p_head_or_embed, x, *, tied: bool):
    t = p_head_or_embed["table"] if tied else p_head_or_embed["w"]
    if tied:
        y = x @ t.astype(x.dtype).T
    else:
        y = x @ t.astype(x.dtype)
    return constraint(y, "batch", None, "tensor")


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def chunked_cross_entropy(x, head, labels, vocab: int, *, tied: bool,
                          chunk: int = 512):
    """Cross-entropy without materialising the full (B, S, V) logits: scan
    over sequence chunks, computing logits + NLL per chunk (the backward
    recomputes each chunk's logits — checkpointed). Used when S*V is large
    (e.g. command-r's 256k vocab)."""
    b, s, d = x.shape
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def body(carry, xs_c):
        nll_sum, n_valid = carry
        x_c, l_c = xs_c
        logits = logits_apply(head, x_c, tied=tied)
        nll, nv = _ce_sums(logits, l_c, vocab)
        return (nll_sum + nll, n_valid + nv), None

    (nll_sum, n_valid), _ = jax.lax.scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xs, ls))
    return nll_sum / jnp.maximum(n_valid, 1)


def _ce_sums(logits, labels, vocab: int):
    vpad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vpad > vocab:
        neg = jnp.full((vpad - vocab,), -1e9, jnp.float32)
        logits = logits + jnp.concatenate(
            [jnp.zeros((vocab,), jnp.float32), neg])
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll), jnp.sum(valid).astype(jnp.float32)


def cross_entropy(logits, labels, vocab: int):
    """Mean token cross-entropy; padded vocab columns are excluded by masking
    against the true vocab size. labels == -100 are ignored."""
    vpad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vpad > vocab:
        neg = jnp.full((vpad - vocab,), -1e9, jnp.float32)
        logits = logits + jnp.concatenate(
            [jnp.zeros((vocab,), jnp.float32), neg])
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
