"""The paper's own model families: modified VGG-11 (CIFAR-10) and modified
ResNet-18 (FEMNIST), plus an MLP for fast benchmark sweeps. Pure JAX
(lax.conv); width_mult scales channel counts for CPU-scale runs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig


def _conv_init(key, cin, cout, ksize):
    fan_in = cin * ksize * ksize
    w = jax.random.normal(key, (cout, cin, ksize, ksize), jnp.float32)
    return w * math.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


VGG11_PLAN = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def init_cnn(key, cfg: CNNConfig):
    ks = iter(jax.random.split(key, 64))
    wm = cfg.width_mult
    params = {}
    if cfg.arch == "mlp":
        d_in = cfg.in_channels * cfg.image_size ** 2
        h = max(int(128 * wm), 16)
        params["fc1"] = {"w": jax.random.normal(next(ks), (d_in, h)) *
                         math.sqrt(2 / d_in), "b": jnp.zeros((h,))}
        params["fc2"] = {"w": jax.random.normal(next(ks), (h, h)) *
                         math.sqrt(2 / h), "b": jnp.zeros((h,))}
        params["out"] = {"w": jax.random.normal(next(ks),
                                                (h, cfg.num_classes)) *
                         math.sqrt(1 / h), "b": jnp.zeros((cfg.num_classes,))}
        return params
    if cfg.arch == "vgg":
        cin = cfg.in_channels
        convs = []
        size = cfg.image_size
        for item in VGG11_PLAN:
            if item == "M":
                if size > 1:
                    size //= 2
                continue
            cout = max(int(item * wm), 8)
            convs.append(_conv_init(next(ks), cin, cout, 3))
            cin = cout
        params["convs"] = convs
        feat = cin * size * size
        params["out"] = {"w": jax.random.normal(next(ks),
                                                (feat, cfg.num_classes)) *
                         math.sqrt(1 / feat),
                         "b": jnp.zeros((cfg.num_classes,))}
        return params
    # resnet-18-ish: stem + 4 stages of 2 basic blocks
    widths = [max(int(c * wm), 8) for c in (64, 128, 256, 512)]
    cin = cfg.in_channels
    params["stem"] = _conv_init(next(ks), cin, widths[0], 3)
    cin = widths[0]
    stages = []
    for si, cout in enumerate(widths):
        blocks = []
        for bi in range(2):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {"c1": _conv_init(next(ks), cin, cout, 3),
                   "c2": _conv_init(next(ks), cout, cout, 3)}
            if stride != 1 or cin != cout:
                blk["proj"] = _conv_init(next(ks), cin, cout, 1)
            blocks.append(blk)
            cin = cout
        stages.append(blocks)
    params["stages"] = stages
    params["out"] = {"w": jax.random.normal(next(ks),
                                            (cin, cfg.num_classes)) *
                     math.sqrt(1 / cin), "b": jnp.zeros((cfg.num_classes,))}
    return params


def apply_cnn(params, cfg: CNNConfig, images):
    """images: (B, C, H, W) -> logits (B, num_classes)."""
    x = images
    if cfg.arch == "mlp":
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]
    if cfg.arch == "vgg":
        ci = 0
        size = cfg.image_size
        for item in VGG11_PLAN:
            if item == "M":
                if size > 1:
                    x = _pool(x)
                    size //= 2
            else:
                x = jax.nn.relu(_conv(x, params["convs"][ci]))
                ci += 1
        x = x.reshape(x.shape[0], -1)
        return x @ params["out"]["w"] + params["out"]["b"]
    x = jax.nn.relu(_conv(x, params["stem"]))
    for si, stage in enumerate(params["stages"]):
        for bi, blk in enumerate(stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = jax.nn.relu(_conv(x, blk["c1"], stride=stride))
            h = _conv(h, blk["c2"])
            sc = _conv(x, blk["proj"], stride=stride) if "proj" in blk else x
            x = jax.nn.relu(h + sc)
    x = jnp.mean(x, axis=(2, 3))
    return x @ params["out"]["w"] + params["out"]["b"]


def cnn_loss(params, cfg: CNNConfig, batch):
    logits = apply_cnn(params, cfg, batch["x"])
    labels = batch["y"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(nll), {"accuracy": acc}
