"""Mixture-of-Experts MLP: top-k softmax router + per-dispatch-group
sort-based capacity dispatch + grouped matmuls with the expert dim sharded
over `model`.

Dispatch is performed independently inside G "dispatch groups" (G = the
`data` mesh axis size), so every sort/scatter/gather is local to a data
shard and partitions trivially; the only cross-device movement is the
(E, G, C, D) resharding boundary before the expert matmuls — the token
all-to-all, inserted by GSPMD. This mirrors per-device dispatch in
production MoE systems (no global sort, no replicated dispatch buffers).

Experts are padded to a multiple of the `model` axis (config
``padded_experts``); router logits for pad columns are -inf so routing
never selects them.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.rules import constraint, get_abstract_mesh_or_none


def moe_init(key, cfg: ModelConfig, experts_padded: int = None):
    moe = cfg.moe
    d, ff = cfg.d_model, moe.expert_ff
    e = experts_padded or moe.experts_padded(1)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "router": layers._normal(ks[0], (d, e), 1 / math.sqrt(d), jnp.float32),
        "wi": layers._normal(ks[1], (e, d, ff), 1 / math.sqrt(d), dtype),
        "wg": layers._normal(ks[2], (e, d, ff), 1 / math.sqrt(d), dtype),
        "wo": layers._normal(ks[3], (e, ff, d), 1 / math.sqrt(ff), dtype),
    }
    lg = {"router": (None, "tensor"),
          "wi": ("tensor", "fsdp", None), "wg": ("tensor", "fsdp", None),
          "wo": ("tensor", None, "fsdp")}
    return p, lg


def _routing_plan(top_e, e: int, cap: int):
    """Sort-based routing plan for one dispatch group.

    The (expert, slot) <-> (token, k-slot) mapping is a PERMUTATION (each
    slot holds at most one token), so dispatch, combine AND both backward
    passes are pure gathers — never a scatter-add, which XLA upcasts to f32
    (doubling all dispatch bytes; see §Perf).
    """
    tk = top_e.size
    flat_e = top_e.reshape(-1)                             # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    ranks = jnp.argsort(order)                             # inverse perm
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = ranks - starts[flat_e]                           # pos within expert
    keep = pos < cap
    pos_k = jnp.where(keep, pos, cap - 1)                  # clamped (masked)
    slot_rank = starts[:, None] + jnp.arange(cap)[None, :]   # (E, cap)
    slot_valid = jnp.arange(cap)[None, :] < jnp.minimum(counts, cap)[:, None]
    tok_idx = jnp.take(order, jnp.clip(slot_rank, 0, tk - 1), axis=0)
    return {"flat_e": flat_e, "pos_k": pos_k, "keep": keep,
            "tok_idx": tok_idx, "slot_valid": slot_valid}


@jax.custom_vjp
def _dispatch_gather(xk, plan):
    """(T*k, D) -> (E, cap, D) by gathering sorted token rows."""
    buf = jnp.take(xk, plan["tok_idx"].reshape(-1), axis=0)
    buf = buf.reshape(plan["tok_idx"].shape + xk.shape[-1:])
    return buf * plan["slot_valid"][..., None].astype(buf.dtype)


def _dg_fwd(xk, plan):
    return _dispatch_gather(xk, plan), plan


def _dg_bwd(plan, ct):
    # ct: (E, cap, D) -> (T*k, D): inverse-permutation GATHER
    e, cap, d = ct.shape
    flat = ct.reshape(e * cap, d)
    lin = plan["flat_e"] * cap + plan["pos_k"]
    ct_xk = jnp.take(flat, lin, axis=0)
    ct_xk = ct_xk * plan["keep"][:, None].astype(ct_xk.dtype)
    return ct_xk, None


_dispatch_gather.defvjp(_dg_fwd, _dg_bwd)


@jax.custom_vjp
def _combine_gather(out_buf, wflat, plan):
    """(E, cap, D) -> (T*k, D) weighted by router probs."""
    e, cap, d = out_buf.shape
    lin = plan["flat_e"] * cap + plan["pos_k"]
    g = jnp.take(out_buf.reshape(e * cap, d), lin, axis=0)
    g = g * plan["keep"][:, None].astype(g.dtype)
    return g * wflat[:, None].astype(g.dtype)


def _cg_fwd(out_buf, wflat, plan):
    return _combine_gather(out_buf, wflat, plan), (out_buf, wflat, plan)


def _cg_bwd(res, ct):
    out_buf, wflat, plan = res
    e, cap, d = out_buf.shape
    ctw = ct * wflat[:, None].astype(ct.dtype)             # (T*k, D)
    # ct_out_buf[e,c] = ctw[tok_idx[e,c]] (permutation gather)
    ct_buf = jnp.take(ctw, plan["tok_idx"].reshape(-1), axis=0)
    ct_buf = ct_buf.reshape(e, cap, d) \
        * plan["slot_valid"][..., None].astype(ct.dtype)
    # ct_w[t] = <gathered[t], ct[t]>
    lin = plan["flat_e"] * cap + plan["pos_k"]
    g = jnp.take(out_buf.reshape(e * cap, d), lin, axis=0)
    g = g * plan["keep"][:, None].astype(g.dtype)
    ct_w = jnp.sum(g.astype(jnp.float32) * ct.astype(jnp.float32), axis=-1)
    return ct_buf, ct_w.astype(wflat.dtype), None


_combine_gather.defvjp(_cg_fwd, _cg_bwd)


def _dispatch_one(xf, top_e, top_w, e: int, cap: int, k: int):
    """Local (per dispatch group) capacity dispatch — pure gathers.

    xf: (T, D); top_e/top_w: (T, k). Returns (buf (E, cap, D),
    combine(out_buf (E, cap, D)) -> (T, D), drop_frac)."""
    t, d = xf.shape
    plan = _routing_plan(top_e, e, cap)
    xk = jnp.repeat(xf, k, axis=0)                         # (T*k, D)
    buf = _dispatch_gather(xk, plan)

    def combine(out_buf):
        wflat = top_w.reshape(-1)
        yk = _combine_gather(out_buf, wflat, plan)
        return yk.reshape(t, k, d).sum(axis=1)

    return buf, combine, 1.0 - jnp.mean(plan["keep"].astype(jnp.float32))


@jax.custom_vjp
def _to_expert_layout(buf):
    """(G, E, C, D) data-sharded -> (E, G, C, D) with BOTH dims sharded
    (E over `model`, G over `data`).

    g and c are batch dims of the expert einsum, so the MLP runs on
    (E/model x G/data) tiles with zero communication; the only real token
    movement is the small per-group all-gather at combine. Without the
    2-D sharding GSPMD lowers this boundary as a full all-gather of the
    expert dim (measured 2.7 GB/op on qwen3-moe, §Perf). The custom VJP
    pins the backward to the mirrored path."""
    buf = constraint(buf, "fsdp", "tensor", None, None)
    return constraint(jnp.swapaxes(buf, 0, 1), "tensor", "fsdp", None, None)


def _tel_fwd(buf):
    return _to_expert_layout(buf), None


def _tel_bwd(_, ct):
    ct = constraint(ct, "tensor", "fsdp", None, None)
    return (constraint(jnp.swapaxes(ct, 0, 1), "fsdp", "tensor", None,
                       None),)


_to_expert_layout.defvjp(_tel_fwd, _tel_bwd)


@jax.custom_vjp
def _from_expert_layout(buf):
    """(E, G, C, D) 2-D sharded -> (G, E, C, D) data-sharded with E FULL per
    group (the combine gather needs every expert's rows for its tokens) —
    this all-gather over `model` is the true token return path."""
    buf = jnp.swapaxes(buf, 0, 1)
    buf = constraint(buf, "fsdp", "tensor", None, None)
    return constraint(buf, "fsdp", None, None, None)


def _fel_fwd(buf):
    return _from_expert_layout(buf), None


def _fel_bwd(_, ct):
    ct = constraint(ct, "fsdp", "tensor", None, None)
    return (constraint(jnp.swapaxes(ct, 0, 1), "tensor", "fsdp", None,
                       None),)


_from_expert_layout.defvjp(_fel_fwd, _fel_bwd)


def moe_apply(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D), plus aux metrics dict."""
    moe = cfg.moe
    b, s, d = x.shape
    e = p["router"].shape[-1]
    k = moe.top_k
    t = b * s
    mesh = get_abstract_mesh_or_none()
    g = mesh.shape.get("data", 1) if mesh is not None else 1
    if t % g != 0:
        g = 1
    tl = t // g
    xf = x.reshape(g, tl, d)
    xf = constraint(xf, "fsdp", None, None)

    logits = (xf.astype(jnp.float32) @ p["router"])        # (G, TL, E)
    if e > moe.num_experts:                                # mask pads
        pad_mask = jnp.arange(e) >= moe.num_experts
        logits = jnp.where(pad_mask[None, None, :], -1e9, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                 # (G, TL, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    top_w = top_w.astype(x.dtype)

    cap = int(math.ceil(tl * k / moe.num_experts * moe.capacity_factor))
    cap = max(cap, 4)

    buf, combine, dropf = _vmapped_dispatch(xf, top_e, top_w, e, cap, k)

    # (G, E, C, D) -> (E, G, C, D): expert-parallel boundary (all-to-all)
    buf = _to_expert_layout(buf)

    wi, wg, wo = (p["wi"].astype(x.dtype), p["wg"].astype(x.dtype),
                  p["wo"].astype(x.dtype))
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", buf, wg)) \
        * jnp.einsum("egcd,edf->egcf", buf, wi)
    h = constraint(h, "tensor", "fsdp", None, None)
    out_buf = jnp.einsum("egcf,efd->egcd", h, wo)
    out_buf = constraint(out_buf, "tensor", "fsdp", None, None)
    out_buf = _from_expert_layout(out_buf)                 # back to (G,E,C,D)

    y = combine(out_buf).reshape(b, s, d)

    # aux: load-balance loss (Switch-style) + drop fraction
    me = jnp.mean(probs, axis=(0, 1))                      # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], e,
                                 dtype=jnp.float32), axis=(0, 1))
    aux = {"load_balance_loss": e * jnp.sum(me * ce),
           "drop_fraction": jnp.mean(dropf)}
    return y, aux


def _vmapped_dispatch(xf, top_e, top_w, e, cap, k):
    """vmap of the gather dispatch over dispatch groups, returning a batched
    combine closure."""
    g, tl, d = xf.shape

    def fwd(xi, ei):
        plan = _routing_plan(ei, e, cap)
        xk = jnp.repeat(xi, k, axis=0)
        buf = _dispatch_gather(xk, plan)
        return buf, plan, 1.0 - jnp.mean(plan["keep"].astype(jnp.float32))

    buf, plans, dropf = jax.vmap(fwd)(xf, top_e)

    def combine(out_buf):  # out_buf: (G, E, C, D)
        def one(ob, wi_, plan):
            yk = _combine_gather(ob, wi_.reshape(-1), plan)
            return yk.reshape(tl, k, d).sum(axis=1)

        return jax.vmap(one)(out_buf, top_w, plans)

    return buf, combine, dropf
