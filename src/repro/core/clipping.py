"""Gradient / update clipping (paper Assumption 1 via [21])."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, clip: float):
    """x <- x / max(1, ||x||/C). Returns (clipped, pre-clip norm)."""
    nrm = global_norm(tree)
    scale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), nrm
