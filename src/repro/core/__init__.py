"""The paper's primary contribution: PFELS — rand_k sparsification, wireless
channel model, Theorem-5 power control, client-level DP accounting, and
AirComp aggregation (simulation + production modes)."""
from repro.core import (aggregation, channel, channels, clipping,
                        compressors, power_control, privacy, randk)

__all__ = ["aggregation", "channel", "channels", "clipping", "compressors",
           "power_control", "privacy", "randk"]
