"""Wireless flat-fading channel model (paper §4.1, §8.1).

|h_i^t| ~ Exponential(mean=0.02), clipped to [1e-4, 0.1]; constant within a
round, redrawn across rounds. Channel noise z^t ~ N(0, sigma_0^2 I_k) at the
receiver. Per-device power limit P_i from max SNR_i = P_i / (d sigma_0^2)
drawn uniformly in [2, 15] dB (paper sets SNR against the full model dim d).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ChannelConfig

PAPER_D = 9_750_922  # the paper's VGG-11 dimension (§8.1)


def scaled_channel(d: int, *, paper_d: int = PAPER_D) -> ChannelConfig:
    """Fading floor scaled to the paper's operating REGIME at a reduced
    model dimension d.

    The power cap floor is ``beta_min ~ gain_min * sqrt(d) * sqrt(SNR)``
    (Eq. 34c with ``P = SNR * d * sigma0^2``), so reproducing the paper's
    regime at reduced d requires scaling the fading floor by
    ``sqrt(d_paper / d)`` — otherwise worst-channel rounds inject
    catastrophically larger relative noise than the paper ever sees. Shared
    by the examples, ``launch/train.py``, and ``benchmarks/common.py``.
    """
    floor = 1e-4 * math.sqrt(paper_d / d)
    return ChannelConfig(gain_clip=(min(floor, 0.05), 0.1))


def sample_gains(key, n: int, cfg: ChannelConfig) -> jnp.ndarray:
    """|h_i| for n devices."""
    g = jax.random.exponential(key, (n,)) * cfg.gain_mean
    return jnp.clip(g, cfg.gain_clip[0], cfg.gain_clip[1])


def sample_power_limits(key, n: int, d: int, cfg: ChannelConfig
                        ) -> jnp.ndarray:
    """P_i from SNR_i ~ U[snr_lo, snr_hi] dB with SNR_i = P_i/(d sigma_0^2)."""
    lo, hi = cfg.snr_db_range
    snr_db = jax.random.uniform(key, (n,), minval=lo, maxval=hi)
    snr = 10.0 ** (snr_db / 10.0)
    return snr * float(d) * cfg.noise_std ** 2


def sample_noise(key, k: int, cfg: ChannelConfig) -> jnp.ndarray:
    """z^t ~ N(0, sigma_0^2 I_k) — the intrinsic receiver noise."""
    return cfg.noise_std * jax.random.normal(key, (k,))


def receive(signals: jnp.ndarray, gains: jnp.ndarray, noise: jnp.ndarray
            ) -> jnp.ndarray:
    """MAC superposition (Eq. 7/11): y = sum_i |h_i| x_i + z.
    signals: (r, k); gains: (r,); noise: (k,)."""
    return jnp.einsum("rk,r->k", signals, gains) + noise


def estimate_gains(key, gains: jnp.ndarray, cfg: ChannelConfig
                   ) -> jnp.ndarray:
    """Imperfect CSI (beyond paper): clients observe h_est = h*(1+eps),
    eps ~ N(0, csi_error^2); precompensation then leaves a residual
    misalignment h/h_est = 1/(1+eps) per client."""
    if cfg.csi_error <= 0:
        return gains
    eps = cfg.csi_error * jax.random.normal(key, gains.shape)
    return gains * jnp.clip(1.0 + eps, 0.1, None)
