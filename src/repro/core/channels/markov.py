"""``markov_fading`` — Gauss–Markov gains correlated across rounds.

LDP-over-wireless analyses hinge on how fading correlates across rounds
("Wireless Federated Learning with Local Differential Privacy"): a client
whose channel is deep in a fade this round is likely still there next
round, so the worst-client β floor persists instead of averaging out.

Construction (Gaussian copula over the paper's marginal): each client i
carries a latent AR(1) state

    z_i^{t+1} = rho * z_i^t + sqrt(1 - rho^2) * xi_i^t,   xi ~ N(0, 1)

with ``rho = cfg.markov_rho`` and stationary N(0, 1) marginal, mapped
through the standard-normal CDF and the Exponential(``gain_mean``)
quantile function to the paper's gain law, then clipped to ``gain_clip``
— so every round's marginal gain distribution matches ``block_fading``
exactly while round-to-round gains correlate.

State: the (n,) latent vector for the WHOLE population (every client's
physical channel evolves every round, sampled or not). It lives in
``TrainState.chan``, evolves from the round's gains lane under both bank
backends (same key, same ops — bit parity), and is (n,)-sized, so it
respects the §10 rule that only O(n) vectors scale with the population.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ChannelConfig
from repro.core import channel
from repro.core.channels.base import (ChannelModel, ChannelRound,
                                      register_channel_model)


def _gains_from_latent(z, cfg: ChannelConfig):
    """N(0,1) latent -> Exponential(gain_mean) marginal, clipped — the
    copula transform (u -> -mean*log(1-u) is the Exp quantile)."""
    u = jax.scipy.special.ndtr(z)
    g = -cfg.gain_mean * jnp.log1p(-u)
    return jnp.clip(g, cfg.gain_clip[0], cfg.gain_clip[1])


def _init(key, n: int, cfg: ChannelConfig):
    # stationary start: z ~ N(0, 1) per client
    return jax.random.normal(key, (n,), jnp.float32)


def _step(carry, cfg: ChannelConfig, r: int, sel, gains_key, csi_key):
    rho = jnp.float32(cfg.markov_rho)
    xi = jax.random.normal(gains_key, carry.shape, jnp.float32)
    z = rho * carry + jnp.sqrt(1.0 - rho * rho) * xi
    gains = _gains_from_latent(z[sel], cfg)
    obs = (channel.estimate_gains(csi_key, gains, cfg)
           if cfg.csi_error > 0 else None)
    return z, ChannelRound(gains=gains, gains_obs=obs)


MODEL = register_channel_model("markov_fading", ChannelModel(
    name="markov_fading",
    init=_init,
    step=_step,
    noise_std=lambda cfg: cfg.noise_std,
    stateful=lambda cfg: True))
