"""``block_fading`` — the paper's flat block-fading MAC (§4.1, §8.1).

A bit-identical extraction of the pre-registry round body: gains are
redrawn i.i.d. every round from ``core.channel.sample_gains`` on the
round's gains lane, the CSI view comes from ``core.channel.estimate_gains``
on the csi lane (skipped entirely under perfect CSI), every sampled client
transmits, and the receiver noise is the raw ``sigma_0``. The golden tier
(``tests/test_golden.py``) pins this equivalence against digests generated
from the pre-registry tree.
"""
from __future__ import annotations

from repro.configs.base import ChannelConfig
from repro.core import channel
from repro.core.channels.base import (ChannelModel, ChannelRound,
                                      register_channel_model)


def _init(key, n: int, cfg: ChannelConfig):
    return None


def _step(carry, cfg: ChannelConfig, r: int, sel, gains_key, csi_key):
    gains = channel.sample_gains(gains_key, r, cfg)
    obs = (channel.estimate_gains(csi_key, gains, cfg)
           if cfg.csi_error > 0 else None)
    return carry, ChannelRound(gains=gains, gains_obs=obs)


MODEL = register_channel_model("block_fading", ChannelModel(
    name="block_fading",
    init=_init,
    step=_step,
    noise_std=lambda cfg: cfg.noise_std))
