"""``mimo_mrc`` — M-antenna base station with maximum-ratio combining.

"Differential Privacy as a Perk: FL over Multiple-Access Fading Channels
with a Multi-Antenna Base Station" shows the receive array is a privacy
knob: combining M antennas multiplies the effective receive SNR by M, so
the *relative* intrinsic channel noise shrinks and the same β buys less
privacy — the ledger must see the post-combining operating point, not the
single-antenna one.

Model (real-magnitude surrogate of MRC, documented in DESIGN.md §11 and
docs/paper_map.md): per-antenna gains ``h_{i,m}`` are i.i.d. draws of the
paper's clipped-Exponential magnitude law; the station combines with the
all-ones beam ``w = 1_M`` (for nonnegative aligned magnitudes this is the
matched filter), giving

    effective gain   g_i = sum_m h_{i,m}            (mean ~ M * gain_mean)
    combined noise   z_c = sum_m z_m ~ N(0, M sigma_0^2)

so ``noise_std`` reports ``sqrt(M) * sigma_0`` — the post-combining noise
the β privacy cap, the receiver draw, and the per-round ε spend all use —
and the per-client SNR g_i^2 / (M sigma_0^2) carries the M-fold array
gain. Devices precompensate with (and the power cap binds on) the
*effective* gain: x_i = (β / g_i^obs) A Δ_i.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.configs.base import ChannelConfig
from repro.core import channel
from repro.core.channels.base import (ChannelModel, ChannelRound,
                                      register_channel_model)


def antenna_gains(key, r: int, cfg: ChannelConfig) -> jnp.ndarray:
    """(r, M) per-antenna magnitudes — ``channel.sample_gains`` (the one
    definition of the clipped-Exp law) drawn for r·M antennas and
    reshaped; the flat threefry stream is bit-identical to a (r, M) draw,
    so M=1 reduces exactly to the scalar channel."""
    m = cfg.num_antennas
    return channel.sample_gains(key, r * m, cfg).reshape(r, m)


def combine_mrc(per_antenna: jnp.ndarray) -> jnp.ndarray:
    """(r, M) -> (r,) post-combining effective gains under the all-ones
    beam (sum over antennas)."""
    return jnp.sum(per_antenna, axis=1)


def _init(key, n: int, cfg: ChannelConfig):
    return None


def _step(carry, cfg: ChannelConfig, r: int, sel, gains_key, csi_key):
    per_ant = antenna_gains(gains_key, r, cfg)
    gains = combine_mrc(per_ant)
    obs = (channel.estimate_gains(csi_key, gains, cfg)
           if cfg.csi_error > 0 else None)
    # gains_ant hands the raw (r, M) matrix to the fused kernel, whose
    # in-tile all-ones-beam combine recomputes exactly combine_mrc
    # (DESIGN.md §12); gains stays the effective view for β design, the
    # CSI estimate, and the unfused oracle
    return carry, ChannelRound(gains=gains, gains_obs=obs,
                               gains_ant=per_ant)


MODEL = register_channel_model("mimo_mrc", ChannelModel(
    name="mimo_mrc",
    init=_init,
    step=_step,
    noise_std=lambda cfg: math.sqrt(cfg.num_antennas) * cfg.noise_std))
