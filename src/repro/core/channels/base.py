"""ChannelModel registry — the pluggable wireless-scenario axis
(DESIGN.md §11).

Mirrors ``repro.fl.algorithms``: a :class:`ChannelModel` entry supplies the
points where wireless scenarios actually differ — per-round gain
generation (possibly stateful across rounds), the observed-gain (CSI)
view, an optional transmit mask, and the post-combining receiver noise
level — while the round body in ``repro.fl.rounds._build_cohort_core``
stays uniform. ``ChannelConfig.model`` selects the entry; new scenarios
are ``register_channel_model`` calls, not round-body branches.

State contract (DESIGN.md §11): a model's cross-round state is an
arbitrary pytree ``carry`` (``None`` for stateless models). It lives in
``TrainState.chan``, is carried through ``Trainer.run``'s ``lax.scan``
(resident bank) and the host loop (streamed bank) with the same update
ops and PRNG lanes — which is why the two backends stay bit-identical —
and checkpoints with the rest of ``TrainState``.

PRNG contract (DESIGN.md §5): ``step`` receives exactly the round's
``gains`` lane (``ks[2]``) and ``csi`` lane (``ks[6]``); models needing
extra draws (the dropout Bernoulli) must derive them by ``fold_in`` on
the gains lane rather than widening the 7-lane split.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional

import jax.numpy as jnp

from repro.configs.base import ChannelConfig

# finite stand-in for "this client does not constrain beta" — kept out of
# inf so beta stays finite (inf * masked-zero signals would produce NaNs)
# while any real gain times sqrt(P) stays orders of magnitude below the
# resulting per-client cap
DESIGN_GAIN_BIG = 1e12


class ChannelRound(NamedTuple):
    """One round's channel realization, as the round body consumes it.

    ``gains``: (r,) true *effective* per-client gains (what the MAC
    applies — post-combining for multi-antenna models). ``gains_obs``:
    the gains the devices observe and precompensate with (``None`` means
    perfect CSI: observed == true, and the aggregation paths skip the
    estimate division entirely — the seed-exact fast path). ``tx_mask``:
    (r,) 0/1 float transmit indicator, or ``None`` when every sampled
    client transmits (again the seed-exact fast path). ``gains_ant``:
    optional (r, M) per-antenna true magnitudes (mimo_mrc) — when set,
    the fused kernel consumes the matrix and performs the all-ones-beam
    MRC combine IN-TILE (DESIGN.md §12); ``gains`` must then equal
    ``sum_m gains_ant[:, m]`` (the effective view the β design and the
    unfused oracle keep using).
    """
    gains: jnp.ndarray
    gains_obs: Optional[jnp.ndarray] = None
    tx_mask: Optional[jnp.ndarray] = None
    gains_ant: Optional[jnp.ndarray] = None


@dataclass(frozen=True)
class ChannelModel:
    """One wireless scenario.

    Hooks (all trace-safe):
      init(key, n, cfg) -> carry
          cross-round channel state for an n-client population (``None``
          for stateless models; the Trainer stores it in
          ``TrainState.chan``).
      step(carry, cfg, r, sel, gains_key, csi_key) -> (carry, ChannelRound)
          one round's realization for the sampled cohort ``sel`` (r,).
          ``gains_key``/``csi_key`` are the round's ks[2]/ks[6] lanes.
      noise_std(cfg) -> float
          POST-COMBINING receiver noise std sigma_eff — consumed by the
          noise draw, the Theorem-5 privacy cap, and the ledger's per-round
          ε spend in place of the raw ``cfg.noise_std``.
      stateful(cfg) -> bool
          whether ``init`` returns real state (a config-static property;
          the deprecated legacy shims reject stateful models — they have
          nowhere to carry the state).
      may_mask(cfg) -> bool
          whether ``step`` can return a non-None ``tx_mask`` (config-
          static, so maskless configs trace the exact seed code path).
    """
    name: str
    init: Callable
    step: Callable
    noise_std: Callable
    stateful: Callable = lambda cfg: False
    may_mask: Callable = lambda cfg: False


_REGISTRY: Dict[str, ChannelModel] = {}


def register_channel_model(name: str, model: ChannelModel, *,
                           overwrite: bool = False) -> ChannelModel:
    """Add a scenario under ``ChannelConfig.model == name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"channel model {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    if model.init is None or model.step is None or model.noise_std is None:
        raise ValueError(f"channel model {name!r} needs init, step and "
                         f"noise_std hooks")
    _REGISTRY[name] = model
    return model


def unregister_channel_model(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_channel_model(name: str) -> ChannelModel:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown channel model {name!r}; registered: "
            f"{sorted(_REGISTRY)} (add new scenarios via "
            f"repro.core.channels.register_channel_model)") from None


def list_channel_models():
    return sorted(_REGISTRY)


# ------------------------------------------------------------ shared views

def effective_noise_std(cfg: ChannelConfig) -> float:
    """sigma_eff of the configured model — the one value the β privacy
    cap, the ledger ε spend, and the receiver noise draw must agree on."""
    return float(get_channel_model(cfg.model).noise_std(cfg))


def observed_gains(cr: ChannelRound) -> jnp.ndarray:
    """The gains the devices precompensate with (true gains under perfect
    CSI)."""
    return cr.gains if cr.gains_obs is None else cr.gains_obs


def design_gains(cr: ChannelRound) -> jnp.ndarray:
    """The (r,) gains β-design should min over: the OBSERVED gains
    (ISSUE 4 — the power cap must hold for the precompensation the
    devices actually apply), with dropped-out clients lifted to
    ``DESIGN_GAIN_BIG`` so they never bind the min (they transmit
    nothing, so no power constraint applies) — the r-realized-vs-
    r-nominal path of the β design."""
    g = observed_gains(cr)
    if cr.tx_mask is None:
        return g
    return jnp.where(cr.tx_mask > 0, g, jnp.float32(DESIGN_GAIN_BIG))


def realized_cohort_size(cr: ChannelRound, r: int) -> jnp.ndarray:
    """f32 count of clients that actually transmitted this round (== r
    unless the model masks transmissions)."""
    if cr.tx_mask is None:
        return jnp.asarray(float(r), jnp.float32)
    return jnp.sum(cr.tx_mask).astype(jnp.float32)
