"""``dropout`` — Bernoulli client dropout over any base fading model.

Real cohorts lose clients between sampling and transmission (battery,
backhaul, local-training stragglers). The wrapper fades by
``cfg.dropout_base`` (any registered non-dropout model) and zeroes a
Bernoulli(``cfg.dropout_prob``) subset of the cohort's transmissions via
``ChannelRound.tx_mask``, which exercises the r-realized-vs-r-nominal
path end to end: β-design mins over the *realized* transmitters only
(dropped clients transmit nothing, so their power limits cannot bind —
``base.design_gains``), the server unscales the AirComp sum by the
realized count (``aggregation``'s ``tx_mask`` paths), and with error
feedback a dropped client's entire update stays in its residual memory.

PRNG (DESIGN.md §5): the Bernoulli draw derives from the round's gains
lane by ``fold_in`` (the documented way to add a draw without widening
the 7-lane split), so the base model's gain stream is untouched — a
``dropout``-wrapped round sees the exact gains of its base model.
"""
from __future__ import annotations

import jax

from repro.configs.base import ChannelConfig
from repro.core.channels.base import (ChannelModel, ChannelRound,
                                      get_channel_model,
                                      register_channel_model)

_MASK_TAG = 0x44524F50  # "DROP": the fold_in stream for the Bernoulli draw


def _base(cfg: ChannelConfig) -> ChannelModel:
    base = get_channel_model(cfg.dropout_base)
    if base.name == "dropout":
        raise ValueError("dropout cannot wrap itself")
    return base


def _init(key, n: int, cfg: ChannelConfig):
    return _base(cfg).init(key, n, cfg)


def _step(carry, cfg: ChannelConfig, r: int, sel, gains_key, csi_key):
    carry, cr = _base(cfg).step(carry, cfg, r, sel, gains_key, csi_key)
    keep = jax.random.bernoulli(
        jax.random.fold_in(gains_key, _MASK_TAG),
        1.0 - cfg.dropout_prob, (r,))
    return carry, cr._replace(tx_mask=keep.astype("float32"))


MODEL = register_channel_model("dropout", ChannelModel(
    name="dropout",
    init=_init,
    step=_step,
    noise_std=lambda cfg: _base(cfg).noise_std(cfg),
    stateful=lambda cfg: _base(cfg).stateful(cfg),
    may_mask=lambda cfg: True))
