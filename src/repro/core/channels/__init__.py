"""Pluggable wireless-scenario registry (DESIGN.md §11).

``ChannelConfig.model`` names an entry here; the round body
(``repro.fl.rounds._build_cohort_core``) consumes the entry's hooks
instead of hard-coding the paper's flat block-fading MAC. Importing this
package registers the four built-in scenarios:

  - ``block_fading``  — the paper's i.i.d. flat fading (seed-exact)
  - ``markov_fading`` — Gauss–Markov gains correlated across rounds
  - ``mimo_mrc``      — M-antenna base station, maximum-ratio combining
  - ``dropout``       — Bernoulli transmission dropout over any base model
"""
from repro.core.channels import (block_fading, dropout, markov,  # noqa: F401
                                 mimo)
from repro.core.channels.base import (DESIGN_GAIN_BIG, ChannelModel,
                                      ChannelRound, design_gains,
                                      effective_noise_std,
                                      get_channel_model, list_channel_models,
                                      observed_gains, realized_cohort_size,
                                      register_channel_model,
                                      unregister_channel_model)

__all__ = [
    "ChannelModel", "ChannelRound", "DESIGN_GAIN_BIG", "design_gains",
    "effective_noise_std", "get_channel_model", "list_channel_models",
    "observed_gains", "realized_cohort_size", "register_channel_model",
    "unregister_channel_model",
]
