"""AirComp aggregation — simulation (exact, Alg. 2) and production
(pod-level psum) modes. See DESIGN.md §3 for the TPU mapping.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import channel as chan
from repro.core import randk
from repro.core.compressors import base as comp_base
from repro.kernels.pfels_transmit import ref as transmit_ref


# ------------------------------------------------------------- simulation

def realized_r(tx_mask, r: int):
    """The server's unscale divisor: the REALIZED transmitter count under
    a channel-model transmit mask (DESIGN.md §11), floored at 1 so an
    all-dropped round reconstructs ~zero (noise/(beta)) instead of NaN;
    the nominal r without a mask."""
    if tx_mask is None:
        return r
    return jnp.maximum(jnp.sum(tx_mask), 1.0)


def aircomp_aggregate(updates_flat, idx, gains, beta, noise_key, *,
                      d: int, sigma0: float, r: int,
                      unbiased_rescale: bool = False,
                      gains_est=None, clip: Optional[float] = None,
                      tx_mask=None, active=None):
    """Exact Alg. 2 lines 12–16 (unfused reference path).

    updates_flat: (r, d) per-client updates Delta_i; idx: (k,) static-width
    support (the compressor's Support.idx, DESIGN.md §13); gains: (r,)
    |h_i|. Clients transmit x_i = (beta/|h_i|) A Delta_i, the MAC
    superposes with gains, noise is added, the server reconstructs
    Delta_hat = A^T y / (r beta).

    gains_est (beyond paper): the gains each client BELIEVES it has
    (imperfect CSI); precompensation uses gains_est while the physical MAC
    applies the true gains, leaving per-client misalignment h/h_est.

    clip: optional per-client transmit-side l2 cap C — each Delta_i is
    scaled by min(1, C/||Delta_i||) before sparsification, enforcing the
    ||Delta|| <= eta tau C1 premise of Theorem 5 even when local training
    overshoots. None disables (seed behavior).

    tx_mask (DESIGN.md §11): optional (r,) 0/1 transmit indicator from the
    channel model (the ``dropout`` scenario) — masked clients contribute
    no signal and no energy, and the server unscales by the REALIZED
    transmitter count instead of the nominal r. None disables (seed
    behavior).

    active (DESIGN.md §13): optional (k,) 0/1 live-slot column of the
    support — deactivated slots carry no signal AND no receiver noise (an
    unused subcarrier is simply not allocated, so nothing is measured on
    it). None disables (seed behavior, every slot live).

    Returns (delta_hat (d,), energy, y (k,)).
    """
    k = idx.shape[0]
    sup = comp_base.as_support(idx, active)
    if clip is not None:
        updates_flat = updates_flat * transmit_ref.clip_scales(
            updates_flat, clip)[:, None]
    proj = jax.vmap(lambda u: comp_base.project(u, sup))(updates_flat)
    comp = gains_est if gains_est is not None else gains
    signals = (beta / comp)[:, None] * proj                         # x_i
    if tx_mask is not None:
        signals = signals * tx_mask[:, None]
    noise = sigma0 * jax.random.normal(noise_key, (k,))
    if active is not None:
        # drawn full-k-shape FIRST (the PRNG-critical draw has a fixed
        # shape across schedules), then masked to the live slots
        noise = noise * active
    y = chan.receive(signals, gains, noise)                         # (k,)
    delta_hat = comp_base.decode_support(y, sup, d) / (
        realized_r(tx_mask, r) * beta)
    if unbiased_rescale:
        delta_hat = delta_hat * (d / k)
    energy = jnp.sum(signals.astype(jnp.float32) ** 2)
    return delta_hat, energy, y


def aircomp_aggregate_fused(updates_flat, idx, gains, beta, noise_key, *,
                            d: int, sigma0: float, r: int,
                            unbiased_rescale: bool = False,
                            gains_est=None, clip: Optional[float] = None,
                            use_kernel: bool = True,
                            interpret: Optional[bool] = None,
                            tx_mask=None, gains_ant=None, active=None):
    """Fused-pipeline variant of :func:`aircomp_aggregate` — identical
    contract and PRNG-noise draw, executed by the ``pfels_transmit`` Pallas
    kernel in one pass over tiles of d with no (r, d) sparsified/scaled
    intermediates. ``use_kernel=False`` runs the pure-JAX fused reference
    (ref.py) instead, for parity testing; ``interpret=None`` compiles the
    kernel on TPU and interprets elsewhere.

    The scenario matrix is fused IN-TILE (DESIGN.md §12): ``tx_mask``
    rides into the kernel as a per-client coefficient column (a dropped
    client contributes zero signal and zero energy without an (r, d)
    pre-masked intermediate — the pre-PR-6 formulation — and the unscale
    divisor is the realized transmitter count, floored at 1);
    ``gains_ant`` (r, M) routes the per-antenna magnitudes to the
    kernel's in-tile MRC combine (``gains`` stays the effective view the
    β design and the unfused oracle consume — ``sum_m h_{i,m}``);
    ``active`` (the Support live-slot column, DESIGN.md §13) folds into
    the kernel's dense mask/noise columns — no kernel change at all."""
    from repro.kernels.pfels_transmit.ops import fused_transmit
    return fused_transmit(
        updates_flat, idx, gains_ant if gains_ant is not None else gains,
        beta, noise_key, d=d, sigma0=sigma0, r=r, clip=clip,
        gains_est=gains_est, tx_mask=tx_mask,
        unbiased_rescale=unbiased_rescale,
        use_kernel=use_kernel, interpret=interpret, active=active)


def aircomp_aggregate_sharded(updates_local, idx, gains_local, beta,
                              noise_key, *, d: int, sigma0: float, r: int,
                              axis_name, unbiased_rescale: bool = False,
                              gains_est_local=None,
                              clip: Optional[float] = None,
                              use_kernel: bool = False,
                              interpret: Optional[bool] = None,
                              tx_mask_local=None, active=None):
    """Sharded-cohort variant of :func:`aircomp_aggregate` (DESIGN.md §7).

    Call INSIDE a ``shard_map`` manual region over ``axis_name`` with this
    shard's (r_local, d) slice of the cohort's updates and (r_local,) slice
    of the channel gains. Each shard computes its partial MAC sum and
    transmit energy — via the fused Pallas kernel (``use_kernel=True``) or
    the dense reference — and the AirComp superposition becomes a physical
    cross-device ``psum`` over ``axis_name``.

    PRNG/noise-identity contract (DESIGN.md §5): the channel noise is drawn
    ONCE from ``noise_key`` — the exact draw of ``aircomp_aggregate`` /
    ``fused_transmit`` — computed replicated on every shard and added AFTER
    the psum, so the sharded round matches the single-device paths to fp32
    accumulation order.

    ``beta`` must be the Theorem-5 coefficient computed from the GLOBAL
    gains (it is a min over all r clients — compute it before entering the
    manual region, or from an all-gather). ``gains_local`` may be the
    (r_local,) effective gains or the (r_local, M) per-antenna matrix
    (mimo_mrc) — the kernel MRC-combines in-tile, the reference through
    ``ref.effective_gains`` (DESIGN.md §12). ``tx_mask_local`` is this
    shard's slice of the channel model's transmit mask (DESIGN.md §11):
    masked rows contribute nothing to the partial MAC sum or energy
    (folded into the per-client coefficients, never an (r, d) pre-masked
    intermediate), and the realized transmitter count — the unscale
    divisor — is itself a ``psum`` over the shards. ``active`` is the
    replicated (k,) live-slot column of the support (DESIGN.md §13),
    folded into the dense mask/noise like the fused path. Returns
    (delta_hat (d,), energy, y (k,)), all replicated over ``axis_name``.
    """
    mask, z_dense = transmit_ref.dense_noise_and_mask(idx, noise_key,
                                                      sigma0, d, active)
    zeros = jnp.zeros((d,), jnp.float32)
    u = updates_local.astype(jnp.float32)
    if use_kernel:
        from repro.kernels.pfels_transmit.ops import fused_pipeline
        y_part, e_part = fused_pipeline(
            u, mask, zeros, gains_local, beta, clip=clip,
            gains_est=gains_est_local, tx_mask=tx_mask_local,
            interpret=interpret)
    else:
        scales = transmit_ref.clip_scales(u, clip)
        tx, rx = transmit_ref.transmit_coeffs(gains_local, beta, scales,
                                              gains_est_local)
        rx_eff, tx_sq = transmit_ref.masked_coeffs(tx, rx, tx_mask_local)
        y_part, e_part = transmit_ref.pfels_transmit_ref(u, mask, zeros,
                                                         rx_eff, tx_sq)
    y_dense = jax.lax.psum(y_part, axis_name) + z_dense
    energy = jax.lax.psum(e_part, axis_name)
    r_div = r
    if tx_mask_local is not None:
        r_div = jnp.maximum(
            jax.lax.psum(jnp.sum(tx_mask_local), axis_name), 1.0)
    delta_hat = transmit_ref.server_unscale(y_dense, idx, beta, r_div, d,
                                            unbiased_rescale)
    return delta_hat, energy, y_dense[idx]


def dp_fedavg_aggregate(updates_flat, clip: float, sigma: float, noise_key, *,
                        r: int):
    """DP-FedAvg baseline (Alg. 1 line 11/13): per-client clip + Gaussian
    noise N(0, C^2 sigma^2 I / r) per client, then average."""
    norms = jnp.linalg.norm(updates_flat, axis=1, keepdims=True)
    clipped = updates_flat / jnp.maximum(1.0, norms / clip)
    noise = clip * sigma / jnp.sqrt(r) * jax.random.normal(
        noise_key, updates_flat.shape[1:])
    return jnp.mean(clipped, axis=0) + noise


def fedavg_aggregate(updates_flat):
    return jnp.mean(updates_flat, axis=0)


# ------------------------------------------------------------- production

def pfels_production_aggregate(update_tree, masks, *, beta, r: int,
                               sigma0: float, noise_key,
                               axis_name: Optional[str] = None,
                               unbiased_rescale: bool = False,
                               compression_p: float = 1.0):
    """PFELS aggregation for pod-scale clients (DESIGN.md §3).

    Each client (pod) holds `update_tree` = its clipped local update. The
    transform is: mask -> scale by beta -> psum over `axis_name` (the AirComp
    superposition; the channel gain is pre-inverted so the received signal is
    beta * A Delta_i) -> + channel noise on the transmitted coordinates ->
    unscale by 1/(r beta).

    Inside a shard_map manual over `axis_name`; pass axis_name=None for the
    single-pod degenerate case (r=1 client, noise still applied).
    """
    masked = randk.apply_mask_tree(update_tree, masks)
    scaled = jax.tree.map(lambda x: x * beta, masked)
    if axis_name is not None:
        summed = jax.tree.map(lambda x: jax.lax.psum(x, axis_name), scaled)
    else:
        summed = scaled
    leaves, treedef = jax.tree.flatten(summed)
    mask_leaves = jax.tree.leaves(masks)
    keys = jax.random.split(noise_key, len(leaves))
    noisy = [
        x + sigma0 * mask.astype(x.dtype)
        * jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype)
        for x, mask, k in zip(leaves, mask_leaves, keys)
    ]
    out = jax.tree.unflatten(treedef, noisy)
    scale = 1.0 / (r * beta)
    if unbiased_rescale:
        scale = scale / compression_p
    return jax.tree.map(lambda x: x * scale, out)
