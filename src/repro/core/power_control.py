"""Convergence-optimized power control under DP (paper §7).

Theorem 5 (PFELS):
    beta*_t = min_i min( |h_i| sqrt(d P_i) / (C1 eta tau sqrt(k)),  eps/C2 )

Baselines:
    WFL-P   (Eq. 36): beta_t = min_i |h_i| sqrt(P_i) / (C1 eta tau)
    WFL-PDP (Eq. 37): beta_t = min( WFL-P beta, eps/C2 )

Lemma 5 bound used for the power term: E||A Delta||^2 <= (k/d) eta^2 tau^2 C1^2,
so the per-device power constraint E||x_i||^2 = (beta/|h_i|)^2 E||A Delta||^2
<= P_i resolves to Eq. (34c).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import privacy


def beta_power_cap(gains, power_limits, d: int, k, c1: float,
                   eta: float, tau: int):
    """Eq. (34c): min_i |h_i| sqrt(d P_i) / (C1 eta tau sqrt(k)).

    ``k`` may be a traced live-support count (a threshold compressor or
    an annealed-k schedule, DESIGN.md §13) — bit-identical to the old
    ``float(k)`` path for static ints."""
    sqrt_k = jnp.sqrt(jnp.asarray(k, jnp.float32))
    per = gains * jnp.sqrt(float(d) * power_limits) / (c1 * eta * tau
                                                       * sqrt_k)
    return jnp.min(per)


def beta_pfels(gains, power_limits, *, d: int, k: int, c1: float, eta: float,
               tau: int, epsilon: float, r: int, n: int, delta: float,
               sigma0: float):
    """Theorem 5: the optimal per-round alignment coefficient."""
    cap_power = beta_power_cap(gains, power_limits, d, k, c1, eta, tau)
    cap_priv = privacy.beta_privacy_cap(epsilon, eta, tau, c1, r, n, delta,
                                        sigma0)
    return jnp.minimum(cap_power, cap_priv)


def beta_wfl_p(gains, power_limits, *, c1: float, eta: float, tau: int):
    """Eq. (36): full updates (k=d), no DP constraint."""
    per = gains * jnp.sqrt(power_limits) / (c1 * eta * tau)
    return jnp.min(per)


def beta_wfl_pdp(gains, power_limits, *, c1: float, eta: float, tau: int,
                 epsilon: float, r: int, n: int, delta: float, sigma0: float):
    """Eq. (37): full updates + DP constraint."""
    cap_power = beta_wfl_p(gains, power_limits, c1=c1, eta=eta, tau=tau)
    cap_priv = privacy.beta_privacy_cap(epsilon, eta, tau, c1, r, n, delta,
                                        sigma0)
    return jnp.minimum(cap_power, cap_priv)


def transmit_energy(beta, gains, signal_sq_norms):
    """Per-round transmit energy Sum_i ||x_i||^2 with x_i = (beta/|h_i|) A d_i:
    signal_sq_norms: (r,) ||A Delta_i||^2."""
    return jnp.sum((beta / gains) ** 2 * signal_sq_norms)
