"""``rand_k`` — the paper's uniform random-k sparsifier (seed-exact).

Alg. 2 line 12: ω_t is a uniform k-subset of [d], shared across clients
(AirComp alignment). ``randk_mode="server_topk"`` (beyond paper) is a
rand-k *mode*, not a separate compressor: half the budget goes to the top
coords of ``|Δ̂_{t-1}|``, half explored uniformly — pure top-k would lock
its support (coords never transmitted keep ``|Δ̂|=0`` and are never
selected), and a cold start (zero/absent ``prev_delta``) falls back to
the uniform sample — top_k over ``|zeros|`` would deterministically pick
coords ``0..k1-1``, biasing round 1.

Sensitivity factor 1.0: the projection is a submatrix of the identity, so
``||A u|| ≤ ||u||`` and the Lemma-2 bound ψ = η τ C1 is unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import randk
from repro.core.compressors.base import (Compressor, Support,
                                         register_compressor)


def select_support(cfg, d: int, k: int, prev_delta, key) -> Support:
    """The exact pre-registry ``algorithms._pfels_support`` draw — moved
    here verbatim so the rand-k goldens stay bit-identical (ISSUE 7)."""
    if cfg.randk_mode == "server_topk" and prev_delta is not None:
        def _warm_idx():
            k1 = k // 2
            _, idx_top = jax.lax.top_k(jnp.abs(prev_delta), k1)
            scores = jax.random.uniform(key, (d,))
            scores = scores.at[idx_top].set(-jnp.inf)
            _, idx_rand = jax.lax.top_k(scores, k - k1)
            return jnp.concatenate([idx_top, idx_rand])

        idx = jax.lax.cond(
            jnp.linalg.norm(prev_delta) > 0, _warm_idx,
            lambda: randk.sample_indices(key, d, k))
    else:
        idx = randk.sample_indices(key, d, k)
    return Support(idx)


register_compressor("rand_k", Compressor(
    name="rand_k", select_support=select_support))
