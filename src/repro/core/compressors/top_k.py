"""``top_k_ef`` — magnitude top-k with mandatory error feedback.

ω_t = the k largest-magnitude coords of the PREVIOUS round's released
aggregate ``Δ̂_{t-1}`` — per-client top-k supports would not align on
shared subcarriers, so the server-guided variant is the one AirComp
admits. Selecting from a DP-released output is post-processing, so the
sensitivity factor stays 1.0 (the arxiv 2304.04164 top-k-under-DP
analysis; docs/paper_map.md).

``carry(cfg) -> True``: pure top-k locks its support — a coordinate never
transmitted keeps ``|Δ̂| = 0`` and is never selected again — so this
entry REQUIRES error-feedback residuals (the round body and the Trainer's
ClientBank turn them on even with ``cfg.error_feedback=False``): the
untransmitted mass accumulates client-side and eventually dominates the
released magnitudes. Cold start (zero ``prev_delta``) falls back to the
uniform rand-k draw from the same support-lane key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import randk
from repro.core.compressors.base import (Compressor, Support,
                                         register_compressor)


def select_support(cfg, d: int, k: int, prev_delta, key) -> Support:
    if prev_delta is None:
        return Support(randk.sample_indices(key, d, k))
    idx = jax.lax.cond(
        jnp.linalg.norm(prev_delta) > 0,
        lambda: jax.lax.top_k(jnp.abs(prev_delta), k)[1],
        lambda: randk.sample_indices(key, d, k))
    return Support(idx)


register_compressor("top_k_ef", Compressor(
    name="top_k_ef", select_support=select_support,
    carry=lambda cfg: True))
