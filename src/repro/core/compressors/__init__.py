"""Pluggable update-compression registry (DESIGN.md §13).

``PFELSConfig.compressor`` names an entry here; the round body
(``repro.fl.rounds._build_cohort_core``) consumes the entry's hooks
instead of hard-coding the paper's rand-k sparsifier. Importing this
package registers the four built-in schemes:

  - ``rand_k``      — the paper's uniform random-k draw (seed-exact),
                      incl. the ``randk_mode="server_topk"`` variant
  - ``top_k_ef``    — magnitude top-k of the released aggregate, with
                      mandatory error feedback (``carry``)
  - ``threshold``   — hard-threshold sparsification, static-width padded
                      via the ``Support.active`` column
  - ``stoch_quant`` — int8/4-bit unbiased stochastic quantization with
                      its own ``1 + sqrt(d)/levels`` sensitivity bound

``schedules`` evaluates ``CompressionSchedule`` (k / power / per-round ε
annealed against the remaining budget) inside the compiled scan.
"""
from repro.core.compressors import (quant, rand_k, schedules,  # noqa: F401
                                    threshold, top_k)
from repro.core.compressors.base import (QUANT_STREAM_TAG, Compressor,
                                         Support, and_active, as_support,
                                         carry_required, decode_support,
                                         dense_mask, get_compressor,
                                         list_compressors, project,
                                         register_compressor,
                                         sensitivity_factor, sparsify,
                                         support_size,
                                         unregister_compressor)

__all__ = [
    "Compressor", "Support", "QUANT_STREAM_TAG", "and_active",
    "as_support", "carry_required", "decode_support", "dense_mask",
    "get_compressor", "list_compressors", "project",
    "register_compressor", "schedules", "sensitivity_factor", "sparsify",
    "support_size", "unregister_compressor",
]
