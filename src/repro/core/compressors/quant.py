"""``stoch_quant`` — QSGD-style stochastic quantization over rand-k.

Support selection is the paper's uniform rand-k draw; on top, each
client's (already transmit-clipped) update is quantized to
``s = 2^(quant_bits-1) - 1`` signed magnitude levels with UNBIASED
stochastic rounding: with ``y = |u_j|/||u|| · s``, the level is
``floor(y) + Bernoulli(y - floor(y))``, rescaled by ``||u||/s``. The
per-client rounding keys are ``fold_in(ks[3], QUANT_STREAM_TAG)`` split
per cohort slot — derived from the support lane per the DESIGN.md §5
7-lane contract (the dropout-channel precedent).

Sensitivity: stochastic rounding perturbs each coordinate by at most one
level (``||u||/s``), so ``||q(u)|| ≤ ||u|| + sqrt(d)·||u||/s =
(1 + sqrt(d)/s)·||u||`` — the DETERMINISTIC worst-case norm inflation.
The factor multiplies the Lemma-2 bound ψ = η τ C1, tightening BOTH the
Theorem-5 power cap (the transmitted signal really can be that much
larger, so β shrinks to keep ``E||x_i||² ≤ P_i``) and the Theorem-3 ε
spend (a larger released norm costs more budget) — threading one static
float through both is what keeps the energy and privacy accounting
consistent (DESIGN.md §13).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compressors.base import Compressor, register_compressor
from repro.core.compressors.rand_k import select_support as _randk_support


def _levels(cfg) -> int:
    s = 2 ** (int(cfg.quant_bits) - 1) - 1
    if s < 1:
        raise ValueError(
            f"quant_bits={cfg.quant_bits} leaves no magnitude levels "
            f"(need quant_bits >= 2)")
    return s


def encode(cfg, updates: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """(rc, d) unbiased stochastic quantization, one key per client."""
    s = float(_levels(cfg))

    def one(u, k):
        u = u.astype(jnp.float32)
        norm = jnp.linalg.norm(u)
        scale = jnp.where(norm > 0, norm, 1.0)
        y = jnp.abs(u) / scale * s
        lo = jnp.floor(y)
        level = lo + (jax.random.uniform(k, u.shape) < (y - lo))
        return jnp.sign(u) * level * (scale / s)

    return jax.vmap(one)(updates, keys)


def sensitivity(cfg, d) -> float:
    if d is None:
        raise ValueError(
            "stoch_quant sensitivity is dimension-dependent "
            "(1 + sqrt(d)/levels); pass the flat model dimension d")
    return 1.0 + (float(d) ** 0.5) / float(_levels(cfg))


register_compressor("stoch_quant", Compressor(
    name="stoch_quant", select_support=_randk_support,
    sensitivity=sensitivity, encode=encode))
