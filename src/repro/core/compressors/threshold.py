"""``threshold`` — hard-threshold sparsification, static-width padded.

A coordinate is eligible when ``|Δ̂_{t-1}| ≥ threshold_frac · max|Δ̂_{t-1}|``
(server-guided, like top_k_ef, so the support aligns across clients).
Under jit the transmitted set must have a static width, so the entry
fills the k budget with the top-scoring coords and DEACTIVATES the slots
below threshold via the :class:`Support.active` column — the effective
support size ``k_used = Σ active`` is traced and flows into the Theorem-5
β design (a smaller live set relaxes the per-device power cap by
``sqrt(k_budget/k_used)``), the receiver, and the ``subcarriers`` metric.

The argmax coordinate always satisfies its own threshold
(``threshold_frac ≤ 1``), so at least one slot is live on warm rounds;
the cold start (zero ``prev_delta``) falls back to a fully-active uniform
rand-k draw. Sensitivity factor 1.0: masked projection only shrinks
norms, and the support comes from a released aggregate (post-processing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import randk
from repro.core.compressors.base import (Compressor, Support,
                                         register_compressor)


def select_support(cfg, d: int, k: int, prev_delta, key) -> Support:
    if prev_delta is None:
        return Support(randk.sample_indices(key, d, k),
                       jnp.ones((k,), jnp.float32))

    def _warm():
        mag = jnp.abs(prev_delta)
        _, idx = jax.lax.top_k(mag, k)
        thresh = cfg.threshold_frac * jnp.max(mag)
        return idx, (mag[idx] >= thresh).astype(jnp.float32)

    def _cold():
        return randk.sample_indices(key, d, k), jnp.ones((k,), jnp.float32)

    idx, active = jax.lax.cond(jnp.linalg.norm(prev_delta) > 0,
                               _warm, _cold)
    return Support(idx, active)


register_compressor("threshold", Compressor(
    name="threshold", select_support=select_support,
    dynamic_support=lambda cfg: True))
