"""Compressor registry — the pluggable update-compression axis
(DESIGN.md §13).

Mirrors ``repro.core.channels``: a :class:`Compressor` entry supplies the
points where compression schemes actually differ — support selection
(which coordinates ride the MAC), the L2-sensitivity factor the privacy
ledger's ε spend consumes, an optional per-client value transform
(``encode``, e.g. stochastic quantization), and whether the scheme
*requires* error-feedback state in ``TrainState`` (``carry``) — while the
round body in ``repro.fl.rounds._build_cohort_core`` stays uniform.
``PFELSConfig.compressor`` selects the entry; new schemes are
``register_compressor`` calls, not round-body branches.

Support contract: under jit the transmitted index set must have a STATIC
width, so ``select_support`` returns a :class:`Support` of ``k`` budget
coordinates plus an optional 0/1 ``active`` column — a compressor whose
effective support is data-dependent (``threshold``) pads to the budget
and deactivates the tail. ``active=None`` is the seed-exact fast path:
every aggregation path then traces the exact pre-registry code.

Sensitivity contract (DESIGN.md §13): ``sensitivity(cfg, d)`` returns a
STATIC python-float multiplier ``s`` on the per-client norm bound
``ψ = η τ C1`` — the Theorem-5 power cap and the Theorem-3 ε spend are
both linear in C1, so threading ``C1·s`` through β design AND the ledger
keeps the energy constraint and the DP guarantee consistent under
norm-inflating transforms (stochastic quantization inflates worst-case
``||q(u)|| ≤ (1 + sqrt(d)/levels)·||u||``). Support selection from the
PREVIOUS round's released aggregate (top-k of ``|Δ̂_{t-1}|``) is
post-processing of a DP output and costs factor 1.0 — the
arxiv 2304.04164 analysis (docs/paper_map.md).

PRNG contract (DESIGN.md §5): ``select_support`` receives exactly the
round's ``support`` lane (``ks[3]``); compressors needing extra draws
(stochastic rounding) must derive them by ``fold_in`` on that lane
rather than widening the 7-lane split — the dropout-channel precedent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import randk

# stochastic-rounding keys are fold_in(ks[3], _QUANT_TAG) then split per
# client — forked off the support lane so the 7-lane round split stays
# pinned (tests/test_bank.py::test_key_lane_contract)
QUANT_STREAM_TAG = 0x5154  # "QT"


class Support(NamedTuple):
    """One round's transmitted coordinate set ω_t, static-width.

    ``idx``: (k,) coordinate ids (the subcarrier map — shared across
    clients, which is what AirComp alignment requires). ``active``:
    optional (k,) 0/1 f32 column deactivating budget slots whose
    coordinates are not actually transmitted this round (data-dependent
    supports, annealed-k schedules). ``None`` means all k slots live —
    the seed-exact fast path every pre-registry code path traces.
    """
    idx: jnp.ndarray
    active: Optional[jnp.ndarray] = None


def as_support(idx, active=None) -> Support:
    """Normalize a raw (k,) index array — the pre-registry aggregation
    contract — or an existing :class:`Support` into a Support."""
    if isinstance(idx, Support):
        return idx if active is None else Support(idx.idx, active)
    return Support(jnp.asarray(idx), active)


def support_size(sup: Support):
    """k_used: the static budget width when every slot is live, else the
    traced live-slot count (f32, for the β design's sqrt(k))."""
    if sup.active is None:
        return sup.idx.shape[0]
    return jnp.sum(sup.active)


def and_active(sup: Support, active: jnp.ndarray) -> Support:
    """Intersect an extra (k,) 0/1 column (the k-schedule) into the
    support."""
    if sup.active is None:
        return Support(sup.idx, active)
    return Support(sup.idx, sup.active * active)


def project(u: jnp.ndarray, sup: Support) -> jnp.ndarray:
    """(d,) -> (k,) client-side projection A u — ``randk.project`` plus
    the live-slot mask. THE single projection every transmit path (fused,
    unfused, sharded, error-feedback residual) routes through."""
    v = randk.project(u, sup.idx)
    return v if sup.active is None else v * sup.active


def decode_support(y: jnp.ndarray, sup: Support, d: int) -> jnp.ndarray:
    """(k,) -> (d,) server-side unprojection A^T y — ``randk.unproject``
    honoring the live-slot mask; the default :class:`Compressor.decode`."""
    vals = y if sup.active is None else y * sup.active
    return randk.unproject(vals, sup.idx, d)


def sparsify(u: jnp.ndarray, sup: Support, d: int) -> jnp.ndarray:
    """A^T A u: what the client actually put on the air, dense — the one
    definition the error-feedback residual and the aggregation paths
    share (ISSUE 7 satellite: ``fl/rounds.py`` no longer re-implements
    the projection)."""
    return decode_support(project(u, sup), sup, d)


def dense_mask(sup: Support, d: int) -> jnp.ndarray:
    """(d,) 0/1 indicator of the live support (the fused kernel's mask
    column)."""
    ones = (jnp.ones(sup.idx.shape, jnp.float32) if sup.active is None
            else sup.active)
    return jnp.zeros((d,), jnp.float32).at[sup.idx].set(ones)


@dataclass(frozen=True)
class Compressor:
    """One update-compression scheme.

    Hooks (all trace-safe except ``sensitivity``/``carry``/
    ``dynamic_support``, which are config-static):
      select_support(cfg, d, k, prev_delta, key) -> Support
          the transmitted coordinate set; ``prev_delta`` is the previous
          round's reconstructed aggregate (zeros/None on cold start) for
          server-guided schemes; ``key`` is the round's support lane.
      sensitivity(cfg, d) -> float
          STATIC multiplier on the per-client norm bound ψ = η τ C1,
          consumed by BOTH the Theorem-5 β design (power + privacy caps)
          and the ledger's Theorem-3 ε spend (C2 is linear in C1).
          ``d`` may be None for host callers of dimension-independent
          schemes.
      encode(cfg, updates (rc, d), keys (rc, 2)) -> (rc, d)
          optional per-client value transform applied after the transmit
          clip and before projection (stochastic quantization); ``keys``
          are per-client fold_in-derived quant keys. None = identity.
      decode(cfg, y (k,), sup, d) -> (d,)
          server-side unprojection; None = :func:`decode_support`.
      carry(cfg) -> bool
          True when the scheme REQUIRES error-feedback residuals in the
          client bank regardless of ``cfg.error_feedback`` (top-k without
          EF starves never-transmitted coordinates forever).
      dynamic_support(cfg) -> bool
          True when ``select_support`` may return a non-None ``active``
          (config-static, so fixed-support schemes trace the exact seed
          code path).
    """
    name: str
    select_support: Callable
    sensitivity: Callable = lambda cfg, d: 1.0
    encode: Optional[Callable] = None
    decode: Optional[Callable] = None
    carry: Callable = lambda cfg: False
    dynamic_support: Callable = lambda cfg: False


_REGISTRY: Dict[str, Compressor] = {}


def register_compressor(name: str, comp: Compressor, *,
                        overwrite: bool = False) -> Compressor:
    """Add a scheme under ``PFELSConfig.compressor == name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"compressor {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    if comp.select_support is None:
        raise ValueError(f"compressor {name!r} needs a select_support hook")
    _REGISTRY[name] = comp
    return comp


def unregister_compressor(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_compressor(name: str) -> Compressor:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown compressor {name!r}; registered: "
            f"{sorted(_REGISTRY)} (add new schemes via "
            f"repro.core.compressors.register_compressor)") from None


def list_compressors():
    return sorted(_REGISTRY)


# ------------------------------------------------------------ shared views

def sensitivity_factor(cfg, d: Optional[int] = None) -> float:
    """The configured compressor's static sensitivity multiplier — the
    one value the β design and the ε ledger must agree on (DESIGN.md
    §13). Config-driven, so host recomputations (``PrivacyLedger``)
    reproduce the in-graph spend exactly."""
    return float(get_compressor(cfg.compressor).sensitivity(cfg, d))


def carry_required(cfg) -> bool:
    """Whether the configured compressor forces error-feedback residuals
    on (``top_k_ef``), independent of ``cfg.error_feedback``."""
    return bool(get_compressor(cfg.compressor).carry(cfg))
