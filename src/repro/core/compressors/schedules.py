"""DP-aware adaptive compression schedules (DESIGN.md §13).

``configs.base.CompressionSchedule`` declares the policy; these helpers
evaluate it TRACE-SAFELY from the round counter ``t`` (an i32 scalar
carried through the compiled scan) and the ledger's running ε spend —
so ``Trainer.run`` stays one ``lax.scan`` program with zero host
round-trips, and the streamed host loop passes the same traced scalars
to its jitted step (the two backends stay bit-identical).

Three annealed knobs, all config-static when inactive (``None`` return =
the seed-exact untouched code path):

  - ``k_active``: the live fraction of the k budget anneals linearly
    from 1 to ``k_end_ratio`` over ``cfg.rounds`` — expressed as a 0/1
    column over the static-width support (DESIGN.md §13 Support
    contract), never a shape change.
  - ``power_scale``: a multiplier on the per-device power limits P_i,
    annealing 1 → ``power_end`` (the Theorem-5 power cap scales by its
    sqrt).
  - ``epsilon_round`` (mode="budget"): the per-round ε ceiling handed to
    the Theorem-5 privacy cap becomes
    ``clip((ε_total − ε_spent) / rounds_left, eps_floor, cfg.epsilon)``
    with ``ε_total = cfg.epsilon · cfg.rounds`` — rounds that underspend
    (power-cap-bound β) return their slack to later rounds. The ceiling
    never exceeds ``cfg.epsilon``, so the ledger's per-round cap (and
    the Theorem-3 guarantee it reports) is untouched.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.configs.base import CompressionSchedule


def _progress(t, rounds: int):
    """Anneal position in [0, 1]: 0 at round 0, 1 at the final round;
    clipped so chunked resume past ``cfg.rounds`` stays at the endpoint."""
    span = float(max(rounds - 1, 1))
    return jnp.clip(jnp.asarray(t, jnp.float32) / span, 0.0, 1.0)


def k_active(sched: CompressionSchedule, cfg, k_budget: int,
             t) -> Optional[jnp.ndarray]:
    """(k_budget,) 0/1 live-slot column for round ``t``, or None when the
    schedule leaves k alone (static — the seed-exact fast path)."""
    if sched.mode == "none" or sched.k_end_ratio >= 1.0:
        return None
    frac = 1.0 + (sched.k_end_ratio - 1.0) * _progress(t, cfg.rounds)
    k_t = jnp.maximum(jnp.floor(frac * k_budget), 1.0)
    return (jnp.arange(k_budget) < k_t).astype(jnp.float32)


def power_scale(sched: CompressionSchedule, cfg, t):
    """Traced P_i multiplier for round ``t``, or None when the schedule
    leaves power alone (static)."""
    if sched.mode == "none" or sched.power_end == 1.0:
        return None
    return 1.0 + (sched.power_end - 1.0) * _progress(t, cfg.rounds)


def epsilon_round(sched: CompressionSchedule, cfg, t, eps_spent):
    """Traced per-round ε ceiling for the Theorem-5 privacy cap, or None
    for the static ``cfg.epsilon`` (modes other than "budget")."""
    if sched.mode != "budget":
        return None
    total = float(cfg.epsilon) * float(cfg.rounds)
    left = jnp.maximum(jnp.asarray(cfg.rounds, jnp.float32)
                       - jnp.asarray(t, jnp.float32), 1.0)
    remaining = jnp.maximum(total - jnp.asarray(eps_spent, jnp.float32),
                            0.0)
    return jnp.clip(remaining / left, sched.eps_floor, cfg.epsilon)


def is_active(sched: CompressionSchedule) -> bool:
    """Whether the schedule changes anything at all (config-static)."""
    return sched.mode != "none"
