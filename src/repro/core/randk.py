"""rand_k sparsification (paper Eq. 9, Lemma 1, Lemma 10).

Two modes:
  - "exact": a uniformly random k-subset omega of [d]; A^t is the 0/1
    projection selecting those coordinates. Used at simulation scale and by
    the Pallas kernels.
  - "mask": seeded Bernoulli(p) masks per parameter tensor — the
    large-model formulation (same shared-PRNG trick the paper uses to avoid
    transmitting A^t; identical first moment, see DESIGN.md §3).

Key identities (tested):
  E_omega[A^T A x] = (k/d) x                     (Lemma 10)
  E_omega ||A^T A x - x||^2 = (1 - k/d) ||x||^2  (Lemma 10)
  E ||A x||^2 = (k/d) ||x||^2                    (Lemma 5 core)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def sample_indices(key, d: int, k: int) -> jnp.ndarray:
    """omega: a uniformly random k-subset of [d] (without replacement)."""
    return jax.random.permutation(key, d)[:k]


def project(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """A^t x: gather the k selected coordinates. x: (d,) -> (k,)."""
    return jnp.take(x, idx, axis=0)


def unproject(y: jnp.ndarray, idx: jnp.ndarray, d: int) -> jnp.ndarray:
    """(A^t)^T y: scatter k values back into d dims (zeros elsewhere)."""
    return jnp.zeros((d,), y.dtype).at[idx].set(y)


def sparsify(x: jnp.ndarray, idx: jnp.ndarray, d: int) -> jnp.ndarray:
    """(A^t)^T A^t x: keep only the selected coordinates of x."""
    return unproject(project(x, idx), idx, d)


# ------------------------------------------------------------- mask mode

def mask_tree(key, tree, p: float):
    """Seeded Bernoulli(p) mask per tensor (large-model rand_k surrogate).
    The same key yields the same masks on every client — the shared-seed
    broadcast of A^t from the paper."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    masks = [
        jax.random.bernoulli(k, p, l.shape) for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, masks)


def apply_mask_tree(tree, masks):
    return jax.tree.map(lambda x, m: x * m.astype(x.dtype), tree, masks)


def compression_ratio_of(k: int, d: int) -> float:
    return k / d


def lambda_k(k: int, d: int) -> float:
    """lambda_k := 1 - k/d (Thm 4 compression-error coefficient)."""
    return 1.0 - k / d
