"""Client-level DP accounting for PFELS (paper §6.1).

Theorem 3: with r-of-N uniform sampling without replacement and intrinsic
channel noise sigma_0, each PFELS round is (eps, delta)-DP provided
    C2 * beta <= eps,   C2 = 2*sqrt(2)*eta*tau*C1*r*sqrt(log(1.25 r/(N delta)))/(N sigma_0).

Lemma 2: l2-sensitivity of the received aggregate is psi <= beta*eta*tau*C1.

Beyond-paper additions (clearly flagged): multi-round composition via basic
and advanced composition so end-to-end (eps_T, delta_T) can be reported; the
paper itself states the per-round guarantee only.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def c2_coefficient(eta: float, tau: int, c1: float, r: int, n: int,
                   delta: float, sigma0: float) -> float:
    """C2 from Eq. (21)."""
    if delta <= 0 or delta >= 1:
        raise ValueError(f"delta must be in (0,1), got {delta}")
    return (2.0 * math.sqrt(2.0) * eta * tau * c1 * r
            * math.sqrt(math.log(1.25 * r / (n * delta)))) / (n * sigma0)


def beta_privacy_cap(epsilon: float, eta: float, tau: int, c1: float,
                     r: int, n: int, delta: float, sigma0: float) -> float:
    """Largest beta satisfying the per-round DP constraint (Thm 3):
    beta <= eps / C2."""
    c2 = c2_coefficient(eta, tau, c1, r, n, delta, sigma0)
    return epsilon / c2


def round_epsilon(beta: float, eta: float, tau: int, c1: float, r: int,
                  n: int, delta: float, sigma0: float) -> float:
    """Per-round eps actually spent for a given beta (inverse of Thm 3)."""
    return c2_coefficient(eta, tau, c1, r, n, delta, sigma0) * beta


def sensitivity_bound(beta: float, eta: float, tau: int, c1: float) -> float:
    """Lemma 2: psi_Delta <= beta * eta * tau * C1."""
    return beta * eta * tau * c1


def gaussian_mechanism_sigma(sensitivity: float, epsilon: float,
                             delta: float) -> float:
    """Thm 1: sigma^2 >= 2 ln(1.25/delta) psi^2 / eps^2."""
    return sensitivity * math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon


def amplified_epsilon(eps0: float, r: int, n: int) -> float:
    """Thm 2 (subsampling): eps' = log(1 + (r/N)(e^eps0 - 1))."""
    return math.log(1.0 + (r / n) * (math.exp(eps0) - 1.0))


# ------------------------------------------------- composition (beyond paper)

def compose_basic(eps_round: float, delta_round: float, rounds: int):
    """(sum eps, sum delta)."""
    return eps_round * rounds, delta_round * rounds


def compose_advanced(eps_round: float, delta_round: float, rounds: int,
                     delta_prime: float = 1e-6):
    """Dwork-Roth advanced composition (Thm 3.20):
    eps_T = sqrt(2 T ln(1/delta')) eps + T eps (e^eps - 1)."""
    e = eps_round
    eps_t = math.sqrt(2.0 * rounds * math.log(1.0 / delta_prime)) * e \
        + rounds * e * (math.exp(e) - 1.0)
    return eps_t, rounds * delta_round + delta_prime


def compose_zcdp(noise_multiplier: float, rounds: int, delta: float):
    """zCDP composition (beyond paper, conservative: no subsampling
    amplification). A Gaussian mechanism with noise multiplier
    z = sigma/sensitivity satisfies rho = 1/(2 z^2) zCDP per round; T
    rounds give T*rho, converted to (eps, delta) via
    eps = rho*T + 2 sqrt(rho*T*log(1/delta))  [Bun & Steinke 2016]."""
    if noise_multiplier <= 0:
        return float("inf"), delta
    rho = rounds / (2.0 * noise_multiplier ** 2)
    return rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta)), delta


def pfels_noise_multiplier(beta: float, eta: float, tau: int, c1: float,
                           sigma0: float) -> float:
    """z = sigma0 / psi with psi the Lemma-2 sensitivity."""
    psi = sensitivity_bound(beta, eta, tau, c1)
    return sigma0 / max(psi, 1e-30)


# ---------------------------------------------- in-graph ledger (DESIGN.md §8)

@dataclass
class LedgerState:
    """Compiled-state privacy accumulators: the jnp twin of
    :class:`PrivacyLedger`, carried inside ``TrainState`` so a ``lax.scan``
    over T rounds (``repro.fl.api.Trainer.run``) returns exact budget totals
    without T host round-trips.

    ``eps_sum`` backs basic composition (sum over rounds), ``eps_max`` backs
    the conservative worst-round advanced composition, and ``spends`` counts
    the rounds actually charged (the per-round delta is a static config
    value, so ``delta_T = delta * spends``). Empty-ledger contract as in
    :class:`PrivacyLedger`: all-zero accumulators total to ``(0.0, 0.0)``.
    """
    eps_sum: jnp.ndarray   # f32 scalar, sum of per-round eps
    eps_max: jnp.ndarray   # f32 scalar, worst per-round eps
    spends: jnp.ndarray    # i32 scalar, number of ledgered rounds


jax.tree_util.register_dataclass(
    LedgerState, data_fields=["eps_sum", "eps_max", "spends"],
    meta_fields=[])


def ledger_init() -> LedgerState:
    return LedgerState(eps_sum=jnp.zeros((), jnp.float32),
                       eps_max=jnp.zeros((), jnp.float32),
                       spends=jnp.zeros((), jnp.int32))


def ledger_spend(ledger: LedgerState, eps_round) -> LedgerState:
    """Charge one round's realized eps (traceable; the in-graph analogue of
    ``PrivacyLedger.spend``)."""
    eps_round = jnp.asarray(eps_round, jnp.float32)
    return LedgerState(eps_sum=ledger.eps_sum + eps_round,
                       eps_max=jnp.maximum(ledger.eps_max, eps_round),
                       spends=ledger.spends + 1)


def ledger_totals_basic(ledger: LedgerState,
                        delta: float) -> Tuple[float, float]:
    """Host-side (eps_T, delta_T) under basic composition — the
    ``PrivacyLedger.total_basic`` contract from compiled accumulators."""
    return float(ledger.eps_sum), delta * int(ledger.spends)


def ledger_totals_advanced(ledger: LedgerState, delta: float,
                           delta_prime: float = 1e-6) -> Tuple[float, float]:
    """Host-side (eps_T, delta_T) under Dwork-Roth advanced composition from
    the worst round's eps (the ``PrivacyLedger.total_advanced`` contract,
    including the (0.0, 0.0) empty-ledger case)."""
    t = int(ledger.spends)
    if t == 0:
        return 0.0, 0.0
    return compose_advanced(float(ledger.eps_max), delta, t, delta_prime)


@dataclass
class PrivacyLedger:
    """Tracks per-round spends over training.

    Empty-ledger contract: both ``total_basic`` and ``total_advanced``
    return the float pair ``(0.0, 0.0)`` before any ``spend`` — nothing was
    released, so no epsilon, delta, or delta' slack is charged.
    """
    n: int
    delta: float
    eps_rounds: Optional[list] = None

    def __post_init__(self):
        if self.eps_rounds is None:
            self.eps_rounds = []

    def spend(self, eps_round: float):
        self.eps_rounds.append(float(eps_round))

    def total_basic(self):
        """(eps_T, delta_T) under basic composition (sum of rounds)."""
        return float(sum(self.eps_rounds)), self.delta * len(self.eps_rounds)

    def total_advanced(self, delta_prime: float = 1e-6):
        """(eps_T, delta_T) under Dwork-Roth advanced composition, using
        the worst round's eps (conservative)."""
        if not self.eps_rounds:
            return 0.0, 0.0
        e = max(self.eps_rounds)   # conservative: worst round
        t = len(self.eps_rounds)
        return compose_advanced(e, self.delta, t, delta_prime)
