"""jit wrapper for the SSD chunk-scan kernel (used by models.mamba2 when
use_kernel=True; interpret=True on CPU)."""
from __future__ import annotations


from repro.kernels.ssd_scan.kernel import ssd_scan as ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = True,
             use_kernel: bool = True):
    if use_kernel:
        return ssd_scan_kernel(x, dt, a, b, c, chunk=chunk,
                               interpret=interpret)
    return ssd_scan_ref(x, dt, a, b, c, chunk)
