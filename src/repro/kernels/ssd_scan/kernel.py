"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunk scan.

Grid = (B, H, n_chunks) with the chunk dim innermost: TPU grids execute
sequentially, so the inter-chunk state recurrence is carried in a VMEM
scratch (P, N) across chunk steps and re-zeroed when (b, h) changes.

Per chunk (all in VMEM, MXU-aligned chunk=128):
  la     = dt * A[h]                       (chunk,)
  cum    = cumsum(la)
  L      = exp(cum_i - cum_j) masked i>=j  (chunk, chunk)
  y      = ((C B^T) * L) @ (x*dt)          intra-chunk
  y     += exp(cum)[:, None] * (C @ state) carried-in states
  state  = exp(cum_last) * state + (B * exp(cum_last - cum))^T @ (x*dt)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref):
    h = pl.program_id(1)
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (chunk, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (chunk,)
    bm = b_ref[0].astype(jnp.float32)                  # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)                  # (chunk, N)
    a = a_ref[h]                                       # scalar (prefetch)

    chunk = x.shape[0]
    la = dt * a                                        # (chunk,)
    cum = jnp.cumsum(la)                               # (chunk,)
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(ii >= jj, seg, -jnp.inf))
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    xdt = x * dt[:, None]                              # (chunk, P)
    y = jnp.dot(cb * decay, xdt, preferred_element_type=jnp.float32)

    # carried-in contribution from previous chunks
    state = state_ref[...]                             # (P, N)
    y += jnp.exp(cum)[:, None] * jnp.dot(
        cm, state.T, preferred_element_type=jnp.float32)

    # state update
    dec_last = jnp.exp(cum[-1] - cum)                  # (chunk,)
    new_state = (jnp.exp(cum[-1]) * state
                 + jnp.dot(xdt.T, bm * dec_last[:, None],
                           preferred_element_type=jnp.float32))
    state_ref[...] = new_state
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _emit_state():
        state_out_ref[0, 0] = new_state.astype(state_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,) f32; b, c: (B,S,N).
    Returns (y (B,S,H,P) f32, final state (B,H,P,N) f32)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    grid = (bsz, h, nc)
    y, state = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci, *_:
                             (bi, ci, hi, 0)),
                pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci, *_:
                             (bi, ci, hi)),
                pl.BlockSpec((1, chunk, n), lambda bi, hi, ci, *_:
                             (bi, ci, 0)),
                pl.BlockSpec((1, chunk, n), lambda bi, hi, ci, *_:
                             (bi, ci, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci, *_:
                             (bi, ci, hi, 0)),
                pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci, *_:
                             (bi, hi, 0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(a.astype(jnp.float32), x, dt, b, c)
    return y, state
