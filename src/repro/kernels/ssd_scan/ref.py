"""Oracle for the SSD chunk scan — re-exports the model's chunked math
(repro.models.mamba2.ssd_chunked is the single source of truth)."""
from repro.models.mamba2 import ssd_chunked as ssd_scan_ref  # noqa: F401
