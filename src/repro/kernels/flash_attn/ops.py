"""jit wrapper for the flash-attention forward kernel."""
from __future__ import annotations

from repro.kernels.flash_attn.kernel import flash_attention_fwd
from repro.kernels.flash_attn.ref import attention_ref


def attention(q, k, v, *, causal=True, window=None, interpret=True,
              use_kernel=True, block_q=256, block_kv=256):
    if use_kernel:
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_kv=block_kv,
                                   interpret=interpret)
    return attention_ref(q, k, v, causal=causal, window=window)
