"""Pallas TPU kernel: flash-attention forward (prefill hot-spot).

Grid = (B*Hkv, n_q_blocks, n_kv_blocks), KV innermost: TPU grids execute
sequentially, so the online-softmax state (m, l, acc) lives in VMEM scratch
across KV steps for a fixed (bh, q-block) and is re-initialised when the
q-block changes. Blocks are MXU-aligned (q/kv block x Dh tiles); the GQA
group dim rides inside the q block (bq rows cover g query heads per KV
head).

This is the §Perf pair-C structure in kernel form: the accumulator never
round-trips HBM between KV blocks (the jnp fallback pays that traffic,
measured -15% step time from block 512→2048; the kernel removes it
entirely on TPU).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window, sq: int, skv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # (bq, Dh)
    k = k_ref[0].astype(jnp.float32)                # (bk, Dh)
    v = v_ref[0].astype(jnp.float32)                # (bk, Dh)
    bq, bk = q.shape[0], k.shape[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    # absolute positions (suffix-aligned). GQA stacks g query heads along
    # the row dim (g, Sq) -> row position = row % Sq
    rq = (qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)) % sq \
        + (skv - sq)
    rk = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= rq >= rk
    if window is not None:
        mask &= rq - rk < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window=None,
                        block_q: int = 256, block_kv: int = 256,
                        interpret: bool = True):
    """q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh) -> (B, Sq, H, Dh)."""
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(dh)
    # fold (B, Hkv) into the leading grid dim; queries of one KV head's
    # group are stacked along the row dim of the q block
    qg = q.reshape(b, sq, hkv, g, dh).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv, g * sq, dh)
    kg = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dh)
    vg = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dh)

    if skv % block_kv != 0:
        block_kv = skv
    # q blocks must not straddle head boundaries: clamp to sq and require
    # divisibility, else fall back to one block per head
    bq = min(block_q, sq)
    if sq % bq != 0:
        bq = sq
    grid = (b * hkv, (g * sq) // bq, skv // block_kv)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, sq=sq, skv=skv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g * sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(b, hkv, g, sq, dh).transpose(0, 3, 1, 2, 4) \
        .reshape(b, sq, h, dh)
