"""Oracle: plain softmax attention (GQA, causal / windowed)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh) -> (B, Sq, H, Dh).
    Positions are aligned suffixes (prefill: Sq == Skv)."""
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    s = s / math.sqrt(dh)
    iq = jnp.arange(sq)[:, None] + (skv - sq)
    ik = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= iq >= ik
    if window is not None:
        mask &= iq - ik < window
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)
