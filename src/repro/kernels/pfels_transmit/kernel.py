"""Pallas TPU kernels: fused PFELS transmit pipeline (Alg. 2 lines 12-15).

The whole (r, d) client-update batch goes through clip -> rand_k select ->
Theorem-5 power scale -> noisy AirComp sum in one pass over column tiles of
d, never materializing an (r, d)-sized sparsified/scaled intermediate.

The rand_k gather is reformulated as a dense 0/1 mask over d (computed once
server-side, O(d) not O(r d)), which removes all data-dependent indexing
from the kernel: each grid step loads an (r, block) tile of the updates,
masks it, reduces over clients with the per-client receive coefficients
(VPU multiply + sublane reduction; an MXU matvec at large r), adds the
pre-scattered channel noise, and accumulates the transmit energy
sum_i tx_i^2 ||m * Delta_i||^2 into a (1, 1) output across the sequential
TPU grid (the same cross-step reduction idiom as clip_norm).

The whole wireless-scenario matrix runs in-tile (DESIGN.md §12):

  - per-client transmit mask (the ``dropout`` scenario): a (r, 1) 0/1
    ``txm`` column zeroes a masked client's MAC contribution AND its
    energy term inside the tile pass — no (r, d) pre-masked intermediate;
  - per-antenna MRC combining (the ``mimo_mrc`` scenario): the gains
    arrive as an (r, M) per-antenna matrix and the all-ones-beam combine
    ``g_i = sum_m h_{i,m}`` happens in-tile, so the kernel applies the
    POST-combining effective gain; single-antenna models pass M=1, for
    which the combine is a bit-exact no-op (a sum over one element).

Two passes, like clip_norm: pass 1 (optional, only when a transmit clip is
set) accumulates per-client squared norms over the full d; the host turns
them into clip scales and per-client coefficients; pass 2 does the fused
combine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _sumsq_kernel(u_ref, out_ref):
    """Accumulate per-client sum of squares across column tiles.
    u_ref: (r, block) VMEM; out_ref: (r, 1) revisited every step."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    u = u_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.sum(u * u, axis=1, keepdims=True)


def _combine_kernel(g_ref, tx_ref, txm_ref, u_ref, m_ref, z_ref,
                    y_ref, e_ref):
    """One fused tile: mask, MRC combine, client-weighted superposition,
    noise, energy.

    g_ref: (r, M) per-antenna true gains (M=1 for scalar channels);
    tx_ref/txm_ref: (r, 1) transmit amplitudes / 0-1 transmit mask, all
    revisited every step; u_ref: (r, block); m_ref/z_ref/y_ref:
    (1, block); e_ref: (1, 1) accumulated across steps.

    The receive coefficient is built in-tile: the all-ones-beam MRC
    combine ``g_i = sum_m h_{i,m}`` (bit-exact identity at M=1), times
    the transmit amplitude, times the transmit mask — so a dropped
    client (txm=0) contributes exactly 0.0 to the MAC sum and 0.0 energy
    without any (r, d) pre-masked intermediate.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        e_ref[0, 0] = jnp.zeros((), jnp.float32)

    g_eff = jnp.sum(g_ref[...].astype(jnp.float32), axis=1, keepdims=True)
    tx = tx_ref[...].astype(jnp.float32)
    txm = txm_ref[...].astype(jnp.float32)
    rxw = g_eff * tx * txm              # (r, 1) masked receive coefficients
    um = u_ref[...].astype(jnp.float32) * m_ref[...].astype(jnp.float32)
    y_ref[...] = (jnp.sum(um * rxw, axis=0, keepdims=True)
                  + z_ref[...]).astype(y_ref.dtype)
    e_ref[0, 0] += jnp.sum((tx * tx * txm)
                           * jnp.sum(um * um, axis=1, keepdims=True))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def client_sumsq(updates: jnp.ndarray, *, block: int = 4096,
                 interpret: bool = True) -> jnp.ndarray:
    """updates: (r, d_pad) with d_pad % block == 0. Returns (r, 1) f32
    per-client squared l2 norms (zero-padding is norm-neutral)."""
    r, d_pad = updates.shape
    grid = (d_pad // block,)
    return pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((r, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((r, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.float32),
        interpret=interpret,
    )(updates)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_combine(updates: jnp.ndarray, mask: jnp.ndarray,
                  noise_dense: jnp.ndarray, gains_mat: jnp.ndarray,
                  tx: jnp.ndarray, tx_mask: jnp.ndarray, *,
                  block: int = 4096, interpret: bool = True):
    """updates: (r, d_pad); mask/noise_dense: (1, d_pad); gains_mat:
    (r, M) per-antenna true gains; tx/tx_mask: (r, 1). d_pad % block == 0.
    Returns (y_dense (1, d_pad), energy (1, 1))."""
    r, d_pad = updates.shape
    m_ant = gains_mat.shape[1]
    grid = (d_pad // block,)
    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, m_ant), lambda i: (0, 0)),
            pl.BlockSpec((r, 1), lambda i: (0, 0)),
            pl.BlockSpec((r, 1), lambda i: (0, 0)),
            pl.BlockSpec((r, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(gains_mat, tx, tx_mask, updates, mask, noise_dense)
