"""jit-compatible wrapper: the fused PFELS transmit pipeline.

``fused_transmit`` has the same contract as
``core.aggregation.aircomp_aggregate`` — same PRNG key => bit-identical
channel-noise draw — plus the optional per-client transmit clip, the
per-client transmit mask (the ``dropout`` scenario), and per-antenna
gains with in-tile MRC combining (the ``mimo_mrc`` scenario) — the whole
registered-channel-model matrix on the fast path (DESIGN.md §12). It pads
d up to a whole number of column tiles (zero pads are mask-annihilated, so
they change nothing), runs the one-or-two Pallas passes, and finishes with
the O(d) server-side unscale. ``interpret=None`` (default) picks the real
compiled kernel on TPU and the Pallas interpreter everywhere else; pass an
explicit bool to override.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.pfels_transmit import ref
from repro.kernels.pfels_transmit.kernel import (LANES, client_sumsq,
                                                 fused_combine)


def _pad_cols(x: jnp.ndarray, d_pad: int) -> jnp.ndarray:
    pad = d_pad - x.shape[-1]
    return jnp.pad(x, ((0, 0), (0, pad))) if pad else x


def fused_pipeline(u: jnp.ndarray, mask: jnp.ndarray, z_dense: jnp.ndarray,
                   gains: jnp.ndarray, beta, *,
                   clip: Optional[float] = None, gains_est=None,
                   tx_mask=None, interpret: Optional[bool] = None,
                   block: int = 4096):
    """Kernel-invoking core shared by :func:`fused_transmit` (whole cohort)
    and ``aggregation.aircomp_aggregate_sharded`` (per-shard client slice,
    zero noise — the channel noise is added once after the cross-device
    psum). u: (r_any, d) f32; mask/z_dense: (d,); gains: (r_any,)
    effective or (r_any, M) per-antenna (combined IN-TILE by the kernel);
    tx_mask: optional (r_any,) 0/1 transmit indicator folded into the
    in-tile coefficients. Returns (y_dense (d,), energy) — the dense
    received signal BEFORE the server-side 1/(r beta) unscale."""
    if interpret is None:   # compiled kernel on TPU, interpreter elsewhere
        interpret = jax.default_backend() != "tpu"
    r, d = u.shape[0], u.shape[-1]
    # pick the tile count first, then round the per-tile width up to a
    # whole number of lanes — pads at most one lane-multiple per tile
    # instead of up to a whole `block` of dead columns (d=4100 with a
    # fixed 4096 block would otherwise process 2x the columns)
    n_tiles = max(1, -(-d // block))
    blk = -(-(-(-d // n_tiles)) // LANES) * LANES
    d_pad = n_tiles * blk
    u_pad = _pad_cols(u, d_pad)
    if clip is not None:
        sumsq = client_sumsq(u_pad, block=blk, interpret=interpret)
        scales = ref.scales_from_norms(jnp.sqrt(sumsq[:, 0]), clip)
    else:
        scales = jnp.ones((r,), jnp.float32)
    g_mat = (gains if gains.ndim == 2 else gains[:, None]).astype(
        jnp.float32)
    tx, _ = ref.transmit_coeffs(gains, beta, scales, gains_est)
    txm = (jnp.ones((r,), jnp.float32) if tx_mask is None
           else tx_mask.astype(jnp.float32))
    y2d, e2d = fused_combine(
        u_pad, _pad_cols(mask[None, :], d_pad),
        _pad_cols(z_dense[None, :], d_pad),
        g_mat, tx.astype(jnp.float32)[:, None], txm[:, None],
        block=blk, interpret=interpret)
    return y2d[0, :d], e2d[0, 0]


def fused_transmit(updates_flat: jnp.ndarray, idx: jnp.ndarray,
                   gains: jnp.ndarray, beta, noise_key, *, d: int,
                   sigma0: float, r, clip: Optional[float] = None,
                   gains_est=None, tx_mask=None,
                   unbiased_rescale: bool = False,
                   use_kernel: bool = True,
                   interpret: Optional[bool] = None,
                   block: int = 4096, active=None):
    """Fused Alg. 2 lines 12-16 for the whole (r, d) update batch.

    updates_flat: (r, d); idx: (k,) rand_k subset; gains: (r,) effective
    |h_i| or (r, M) per-antenna magnitudes (MRC-combined in-tile);
    clip: optional per-client l2 cap C on the transmitted update
    (s_i = min(1, C/||Delta_i||), applied before power scaling);
    tx_mask: optional (r,) 0/1 transmit indicator — masked clients
    contribute no signal and no energy (folded into the in-tile
    coefficients, DESIGN.md §12), and the server unscales by the
    REALIZED transmitter count (floored at 1) instead of the nominal r.

    ``sigma0`` must already be the channel model's POST-combining
    ``sigma_eff`` (``sqrt(M) sigma_0`` for mimo_mrc) — the noise draw is
    the single PRNG-critical draw shared with the unfused path
    (``ref.dense_noise_and_mask``).

    ``active``: optional (k,) 0/1 live-slot column of the support
    (DESIGN.md §13) — folded into the dense mask/noise columns by
    ``ref.dense_noise_and_mask``, so the kernel itself is untouched (a
    deactivated slot is just a masked-off column in-tile).

    Returns (delta_hat (d,), energy, y (k,)) exactly like
    ``aircomp_aggregate``.
    """
    mask, z_dense = ref.dense_noise_and_mask(idx, noise_key, sigma0, d,
                                             active)
    u = updates_flat.astype(jnp.float32)
    r_div = r if tx_mask is None else jnp.maximum(jnp.sum(tx_mask), 1.0)

    if use_kernel:
        y_dense, energy = fused_pipeline(
            u, mask, z_dense, gains, beta, clip=clip, gains_est=gains_est,
            tx_mask=tx_mask, interpret=interpret, block=block)
    else:
        scales = ref.clip_scales(u, clip)
        tx, rx = ref.transmit_coeffs(gains, beta, scales, gains_est)
        rx_eff, tx_sq = ref.masked_coeffs(tx, rx, tx_mask)
        y_dense, energy = ref.pfels_transmit_ref(u, mask, z_dense, rx_eff,
                                                 tx_sq)

    delta_hat = ref.server_unscale(y_dense, idx, beta, r_div, d,
                                   unbiased_rescale)
    return delta_hat, energy, y_dense[idx]
