"""Oracle for the fused PFELS transmit pipeline (Alg. 2 lines 12-15).

The whole client-side transmit chain for the (r, d) update batch in one
place: per-client l2 clip -> rand_k selection (dense 0/1 mask over d) ->
Theorem-5 power scaling beta/|h_i| -> MAC superposition with the true gains
-> receiver noise on the selected subcarriers. Unlike the Pallas kernel this
reference is free to materialize (r, d) intermediates — it is the parity
oracle, not the fast path.

Dense-mask formulation: with m the 0/1 indicator of omega and z_dense the
noise scattered onto omega,
    y_dense = sum_i |h_i| (beta/|h_i^est|) s_i (m * Delta_i) + z_dense
where s_i = min(1, C/||Delta_i||) is the optional transmit clip. y_dense is
zero off omega, so Delta_hat = y_dense/(r beta) directly; the k-subcarrier
payload is y_dense[omega].
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_noise_and_mask(idx: jnp.ndarray, noise_key, sigma0: float,
                         d: int, active: Optional[jnp.ndarray] = None):
    """(mask, z_dense): the 0/1 indicator of omega and the channel noise
    scattered onto it. THE single PRNG-critical noise draw
    (``sigma0 * normal(noise_key, (k,))``) shared by the fused and sharded
    AirComp paths — parity across execution modes (DESIGN.md §5) depends
    on every path taking it from here. ``active`` is the support's
    optional (k,) 0/1 live-slot column (DESIGN.md §13): the draw keeps
    its fixed k shape (the PRNG stream is schedule-independent), then
    deactivated slots are zeroed out of BOTH columns — no signal, no
    measured noise on an unallocated subcarrier."""
    noise = sigma0 * jax.random.normal(noise_key, (idx.shape[0],))
    if active is None:
        mask = jnp.zeros((d,), jnp.float32).at[idx].set(1.0)
    else:
        noise = noise * active
        mask = jnp.zeros((d,), jnp.float32).at[idx].set(active)
    z_dense = jnp.zeros((d,), jnp.float32).at[idx].set(noise)
    return mask, z_dense


def server_unscale(y_dense: jnp.ndarray, idx: jnp.ndarray, beta, r,
                   d: int, unbiased_rescale: bool = False) -> jnp.ndarray:
    """Receiver-side reconstruction Delta_hat = y_dense/(r beta), with the
    optional beyond-paper d/k unbiasing — the common tail of every
    aggregation path. ``r`` is the unscale divisor: the static nominal
    cohort size, or the traced REALIZED transmitter count under a channel
    transmit mask (DESIGN.md §11)."""
    delta_hat = y_dense / (r * beta)
    if unbiased_rescale:
        delta_hat = delta_hat * (d / idx.shape[0])
    return delta_hat


def scales_from_norms(norms: jnp.ndarray, clip: float) -> jnp.ndarray:
    """s = min(1, C/||.||) with the shared zero-norm guard — the single
    definition of the clip scale used by the fused kernel path, the fused
    reference, and the unfused aircomp_aggregate (parity depends on all
    three agreeing, epsilon included)."""
    return jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))


def clip_scales(updates: jnp.ndarray, clip: Optional[float]) -> jnp.ndarray:
    """Per-client s_i = min(1, C/||Delta_i||_2) over the FULL update (the
    norm bound of Assumption 1 covers every coordinate, not just omega).
    clip=None disables (s_i = 1)."""
    if clip is None:
        return jnp.ones((updates.shape[0],), jnp.float32)
    return scales_from_norms(jnp.linalg.norm(updates.astype(jnp.float32),
                                             axis=1), clip)


def effective_gains(gains: jnp.ndarray) -> jnp.ndarray:
    """(r,) post-combining effective gains from a (r,) scalar-channel
    vector (identity) or a (r, M) per-antenna matrix (the all-ones-beam
    MRC combine ``g_i = sum_m h_{i,m}`` — bit-exact identity at M=1).
    The single definition the fused kernel's in-tile combine, this
    oracle, and the β design must agree on (DESIGN.md §12)."""
    return gains if gains.ndim == 1 else jnp.sum(gains, axis=-1)


def transmit_coeffs(gains, beta, scales, gains_est=None):
    """(tx, rx): tx_i = (beta/|h_i^est|) s_i is the per-client transmit
    amplitude; rx_i = |h_i| tx_i is the coefficient the MAC applies to
    Delta_i at the receiver (perfect CSI: rx_i = beta s_i). ``gains``
    may be (r,) effective or (r, M) per-antenna (combined here); the
    observed ``gains_est`` is always the effective view — devices
    precompensate with the post-combining gain they experience."""
    eff = effective_gains(gains)
    comp = gains_est if gains_est is not None else eff
    tx = (beta / comp) * scales
    return tx, eff * tx


def masked_coeffs(tx, rx, tx_mask=None):
    """(rx_eff, tx_sq): the receive coefficients and squared transmit
    amplitudes with an optional (r,) 0/1 transmit mask folded in — a
    masked client contributes zero signal and zero energy. This O(r)
    fold is the unfused analogue of the kernel's in-tile ``txm``
    column; both paths mask via the coefficients, never via an (r, d)
    pre-masked intermediate (DESIGN.md §12)."""
    tx_sq = tx * tx
    if tx_mask is None:
        return rx, tx_sq
    return rx * tx_mask, tx_sq * tx_mask


def pfels_transmit_ref(updates: jnp.ndarray, mask: jnp.ndarray,
                       noise_dense: jnp.ndarray, rx_coeffs: jnp.ndarray,
                       tx_sq: jnp.ndarray):
    """Fused combine, dense formulation (the part the Pallas kernel fuses).

    updates: (r, d); mask: (d,) 0/1 indicator of omega; noise_dense: (d,)
    channel noise scattered onto omega; rx_coeffs: (r,) receive-side
    per-client coefficients; tx_sq: (r,) squared transmit amplitudes.

    Returns (y_dense (d,), energy scalar):
        y_dense = sum_i rx_i (m * Delta_i) + z_dense
        energy  = sum_i tx_i^2 ||m * Delta_i||^2      (= sum_i ||x_i||^2)
    """
    masked = updates.astype(jnp.float32) * mask[None, :]
    y_dense = jnp.einsum("rd,r->d", masked, rx_coeffs) + noise_dense
    energy = jnp.sum(tx_sq * jnp.sum(masked * masked, axis=1))
    return y_dense, energy


def client_sumsq_ref(updates: jnp.ndarray) -> jnp.ndarray:
    """Per-client squared l2 norms, (r,) — pass 1 of the clip."""
    u = updates.astype(jnp.float32)
    return jnp.sum(u * u, axis=1)
