# TPU Pallas kernels for the paper's compute hot-spots:
#   pfels_transmit   — FUSED clip -> rand_k -> power scale -> noisy AirComp
#                      sum for the whole (r, d) batch (Alg. 2 lines 12-15),
#                      one pass over d-tiles, no (r, d) intermediates
#   randk_gather     — A^t Delta + beta-scale (client transmit path)
#   aircomp_combine  — (A^t)^T y / (r beta) scatter + unscale (server path)
#   clip_norm        — fused two-pass l2 clip (Assumption 1)
#   ssd_scan         — Mamba2 SSD chunk scan (ssm/hybrid archs)
#   flash_attn       — flash-attention forward (prefill hot-spot)
# Each: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
# interpret=True on CPU), ref.py (pure-jnp oracle).
