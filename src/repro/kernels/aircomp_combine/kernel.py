"""Pallas TPU kernel: AirComp server combine (Alg. 2 lines 15-16).

Fuses (A^t)^T y / (r beta) with the global-model update: the received
k-subcarrier payload is unscaled and scatter-added into theta in one pass.
theta is aliased input->output (in-place rows); omega in SMEM via scalar
prefetch; each index block updates its rows through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _kernel(idx_ref, inv_ref, y_ref, theta_ref, out_ref):
    """grid dim 0 walks index blocks. y_ref: (block, LANES) VMEM;
    theta_ref/out_ref: (rows, LANES) ANY, aliased."""
    i = pl.program_id(0)
    block = y_ref.shape[0]
    inv = inv_ref[0, 0]

    def body(j, _):
        row = idx_ref[i * block + j]
        out_ref[row, :] = (theta_ref[row, :]
                           + (y_ref[j, :] * inv).astype(out_ref.dtype))
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def aircomp_combine(theta_rows: jnp.ndarray, y_rows: jnp.ndarray,
                    idx_rows: jnp.ndarray, inv_rbeta, *,
                    block: int = 256, interpret: bool = True) -> jnp.ndarray:
    """theta_rows: (R, 128); y_rows: (k_rows, 128); idx_rows: (k_rows,).
    Returns theta with the reconstructed update added in-place."""
    k_rows = idx_rows.shape[0]
    if k_rows % block != 0:
        block = k_rows
    grid = (k_rows // block,)
    inv2d = jnp.asarray(inv_rbeta, y_rows.dtype).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block, LANES), lambda i, *_: (i, 0)),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
        ),
        out_shape=jax.ShapeDtypeStruct(theta_rows.shape, theta_rows.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(idx_rows, inv2d, y_rows, theta_rows)
