"""Oracle: server-side reconstruction Delta_hat = (A^t)^T y / (r beta),
fused with the global-model add theta <- theta + Delta_hat (Alg. 2 15-16)."""
from __future__ import annotations

import jax.numpy as jnp


def aircomp_combine_ref(theta_rows: jnp.ndarray, y_rows: jnp.ndarray,
                        idx_rows: jnp.ndarray, inv_rbeta) -> jnp.ndarray:
    """theta_rows: (R, 128); y_rows: (k_rows, 128) received subcarrier
    payload; idx_rows: (k_rows,). Returns updated theta_rows."""
    upd = y_rows * inv_rbeta
    return theta_rows.at[idx_rows].add(upd.astype(theta_rows.dtype))
