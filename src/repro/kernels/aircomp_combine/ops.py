"""jit wrapper for the AirComp server combine kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.aircomp_combine.kernel import LANES, aircomp_combine
from repro.kernels.aircomp_combine.ref import aircomp_combine_ref


def combine(theta_flat: jnp.ndarray, y_payload: jnp.ndarray,
            idx_rows: jnp.ndarray, r: int, beta, *,
            interpret: bool = True, use_kernel: bool = True):
    """theta_flat: (d,); y_payload: (k_rows*128,) received signal;
    idx_rows: (k_rows,). Returns updated theta (d,)."""
    d = theta_flat.shape[0]
    assert d % LANES == 0
    theta_rows = theta_flat.reshape(d // LANES, LANES)
    y_rows = y_payload.reshape(-1, LANES)
    inv = 1.0 / (r * beta)
    if use_kernel:
        out = aircomp_combine(theta_rows, y_rows, idx_rows, inv,
                              interpret=interpret)
    else:
        out = aircomp_combine_ref(theta_rows, y_rows, idx_rows, inv)
    return out.reshape(-1)
