"""jit wrapper: full-vector rand_k gather+scale through the Pallas kernel.

The flat update Delta (d,) is viewed as (d/128, 128) lane-aligned rows and
omega indexes rows (DESIGN.md: rand_k over 128-coordinate rows is the
TPU-native mapping — gathers stay lane-aligned). ``interpret=True`` runs the
kernel body on CPU; on TPU pass interpret=False.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.randk_gather.kernel import LANES, randk_gather
from repro.kernels.randk_gather.ref import randk_gather_ref


def gather_rows(delta_flat: jnp.ndarray, idx_rows: jnp.ndarray, scale,
                *, interpret: bool = True, use_kernel: bool = True):
    """delta_flat: (d,) with d % 128 == 0; idx_rows: (k_rows,) int32.
    Returns the scaled gathered payload (k_rows * 128,)."""
    d = delta_flat.shape[0]
    assert d % LANES == 0, d
    rows = delta_flat.reshape(d // LANES, LANES)
    if use_kernel:
        out = randk_gather(rows, idx_rows, jnp.asarray(scale,
                                                       delta_flat.dtype),
                           interpret=interpret)
    else:
        out = randk_gather_ref(rows, idx_rows,
                               jnp.asarray(scale, delta_flat.dtype))
    return out.reshape(-1)


def row_indices_from_coords(key, d: int, k: int):
    """Sample rand_k over lane-aligned rows: k/128 of the d/128 rows."""
    rows = d // LANES
    k_rows = max(k // LANES, 1)
    return jax.random.permutation(key, rows)[:k_rows]
