"""Oracle: x_i = beta/|h_i| * A^t Delta — gather k coordinates + scale."""
from __future__ import annotations

import jax.numpy as jnp


def randk_gather_ref(delta: jnp.ndarray, idx: jnp.ndarray,
                     scale: jnp.ndarray | float) -> jnp.ndarray:
    """delta: (d,); idx: (k,) int32; scale: scalar. Returns (k,)."""
    return jnp.take(delta, idx, axis=0) * scale
