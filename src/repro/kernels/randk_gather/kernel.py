"""Pallas TPU kernel: rand_k gather + power scale (Alg. 2 line 12).

The transmit-path hot spot: x_i = (beta/|h_i|) * A^t Delta_i. The index
vector omega lives in SMEM via PrefetchScalarGridSpec (the TPU idiom for
data-dependent gathers); Delta stays in HBM/ANY and each index block DMA-
gathers its rows through VMEM, fusing the scale.

Layout: Delta is viewed as (d/L, L) rows of L=128 lanes; omega indexes ROWS
(the paper's rand_k over coordinates maps to rand_k over 128-lane rows so
gathers stay lane-aligned on the VPU — see DESIGN.md hardware adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _kernel(idx_ref, scale_ref, delta_ref, out_ref):
    """Grid dim 0 walks index blocks; rows gathered one DMA each.

    idx_ref: (k_rows,) SMEM (scalar-prefetch); scale_ref: (1, 1) SMEM;
    delta_ref: (rows, LANES) ANY; out_ref: (block, LANES) VMEM.
    """
    i = pl.program_id(0)
    block = out_ref.shape[0]
    scale = scale_ref[0, 0]

    def body(j, _):
        row = idx_ref[i * block + j]
        out_ref[j, :] = delta_ref[row, :] * scale
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def randk_gather(delta_rows: jnp.ndarray, idx_rows: jnp.ndarray,
                 scale: jnp.ndarray, *, block: int = 256,
                 interpret: bool = True) -> jnp.ndarray:
    """delta_rows: (R, 128); idx_rows: (k_rows,) int32 row indices;
    scale: scalar. Returns (k_rows, 128)."""
    k_rows = idx_rows.shape[0]
    if k_rows % block != 0:
        block = k_rows
    grid = (k_rows // block,)
    scale2d = jnp.asarray(scale, delta_rows.dtype).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((block, LANES), lambda i, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((k_rows, LANES), delta_rows.dtype),
        interpret=interpret,
    )(idx_rows, scale2d, delta_rows)
