"""Oracle: fused l2 clip x <- x * min(1, C/||x||) (Assumption 1)."""
from __future__ import annotations

import jax.numpy as jnp


def clip_norm_ref(x: jnp.ndarray, clip: float):
    nrm = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
    scale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))
    return (x * scale).astype(x.dtype), nrm
