"""jit wrapper: flat-vector l2 clip through the Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.clip_norm.kernel import LANES, clip_norm
from repro.kernels.clip_norm.ref import clip_norm_ref


def clip_flat(x_flat: jnp.ndarray, clip: float, *, interpret: bool = True,
              use_kernel: bool = True):
    d = x_flat.shape[0]
    pad = (-d) % LANES
    x = jnp.pad(x_flat, (0, pad)) if pad else x_flat
    rows = x.reshape(-1, LANES)
    if use_kernel:
        out, nrm = clip_norm(rows, clip)
    else:
        out, nrm = clip_norm_ref(rows, clip)
    out = out.reshape(-1)
    return (out[:d] if pad else out), nrm
