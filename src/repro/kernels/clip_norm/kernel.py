"""Pallas TPU kernels: fused two-pass l2-norm clip.

Pass 1 accumulates the squared norm across row blocks into a (1,1) SMEM-
sized output (TPU grids are sequential, so cross-step accumulation into the
same output block is the standard reduction idiom). Pass 2 rescales blocks
by min(1, C/||x||).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _sumsq_kernel(x_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = jnp.zeros((), jnp.float32)

    xf = x_ref[...].astype(jnp.float32)
    out_ref[0, 0] += jnp.sum(xf * xf)


def _scale_kernel(s_ref, x_ref, out_ref):
    out_ref[...] = (x_ref[...].astype(jnp.float32)
                    * s_ref[0, 0]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def clip_norm(x_rows: jnp.ndarray, clip: float, *, block: int = 512,
              interpret: bool = True):
    """x_rows: (R, 128). Returns (clipped (R,128), norm scalar)."""
    r = x_rows.shape[0]
    if r % block != 0:
        block = r
    grid = (r // block,)
    sumsq = pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x_rows)
    nrm = jnp.sqrt(sumsq[0, 0])
    scale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12)
                        ).reshape(1, 1).astype(jnp.float32)
    out = pl.pallas_call(
        _scale_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((block, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x_rows.shape, x_rows.dtype),
        interpret=interpret,
    )(scale, x_rows)
    return out, nrm
