"""Minibatch sampling inside jit (stateless, key-driven)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_batch(key, x, y, batch_size: int):
    idx = jax.random.randint(key, (batch_size,), 0, x.shape[0])
    return {"x": x[idx], "y": y[idx]}


def epoch_batches(n: int, batch_size: int):
    """Static batch count for one epoch (paper runs tau epochs/round)."""
    return max(n // batch_size, 1)
