"""Data pipelines for the FL loop.

Two regimes (DESIGN.md §10):

- **Resident**: the whole federated dataset is a device tensor
  ``(n, samples, ...)`` and the round gathers ``data_x[sel]`` in-graph —
  fine while n is small, and the bit-exact reference.
- **Streamed**: the population lives behind a :class:`CohortSource` and
  only the sampled r-client cohort batch ``(r, samples, ...)`` keyed by
  the round's ``sel`` is materialized, double-buffer prefetched onto the
  device by :func:`prefetch_cohorts` while the previous round computes.
  Device (and, with a generator-backed source, host) memory is then
  independent of the population size n.

Plus the stateless in-jit minibatch sampler used by per-client local
training (``sample_batch``).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def sample_batch(key, x, y, batch_size: int):
    idx = jax.random.randint(key, (batch_size,), 0, x.shape[0])
    return {"x": x[idx], "y": y[idx]}


def epoch_batches(n: int, batch_size: int):
    """Static batch count for one epoch (paper runs tau epochs/round)."""
    return max(n // batch_size, 1)


# ------------------------------------------------------- cohort sources

class CohortSource:
    """A population of n clients addressable by cohort: ``cohort(sel)``
    returns the ``(r, samples, ...)`` data batch for the selected client
    ids — the streamed replacement for the in-graph ``data_x[sel]``
    gather. Implementations must be deterministic in ``sel`` (the same
    client always serves the same samples), which is what makes the
    streamed bank bit-identical to the resident path."""

    n: int

    def cohort(self, sel) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError


class ArraySource(CohortSource):
    """Host-array-backed source: the ``(n, samples, ...)`` tensors stay in
    host memory (numpy) and ``cohort`` is a row gather. The small-n /
    parity-testing source."""

    def __init__(self, x, y):
        self.x = np.asarray(x)
        self.y = np.asarray(y)
        self.n = int(self.x.shape[0])

    def cohort(self, sel):
        sel = np.asarray(sel)
        return self.x[sel], self.y[sel]


class ClientFnSource(CohortSource):
    """Generator-backed source for populations too large to materialize:
    ``cohort_fn(sel) -> (cx, cy)`` synthesizes (or fetches) the selected
    clients' samples on demand — O(r), never O(n), in any memory.
    ``repro.data.make_population_source`` builds the synthetic one."""

    def __init__(self, cohort_fn: Callable, n: int):
        self._cohort_fn = cohort_fn
        self.n = int(n)

    def cohort(self, sel):
        return self._cohort_fn(np.asarray(sel))


def as_cohort_source(data_x, data_y=None) -> CohortSource:
    """Normalize the Trainer's ``(data_x, data_y)`` arguments: pass a
    :class:`CohortSource` through, wrap array pairs in an
    :class:`ArraySource`."""
    if isinstance(data_x, CohortSource):
        if data_y is not None:
            raise ValueError("pass either (data_x, data_y) arrays or a "
                             "CohortSource, not both")
        return data_x
    if data_y is None:
        raise ValueError("data_y is required when data_x is an array")
    return ArraySource(data_x, data_y)


# ------------------------------------------------------------- prefetch

_STOP = object()


class _PrefetchError:
    def __init__(self, exc: BaseException):
        self.exc = exc


def prefetch_cohorts(source: CohortSource, sels: Iterable,
                     depth: int = 2,
                     device_put: Optional[Callable] = None):
    """Double-buffered host→device cohort pipeline (DESIGN.md §10).

    A background thread walks the per-round selections ``sels``, gathers
    each cohort from ``source`` and stages it on device, keeping up to
    ``depth`` cohorts in flight — so the host gather (and host→device
    copy) of round t+1 overlaps the device compute of round t. Yields
    ``(cx, cy)`` device arrays in round order; worker exceptions re-raise
    at the consuming round.
    """
    put = device_put if device_put is not None else (
        lambda a: jax.device_put(jnp.asarray(a)))
    q: queue.Queue = queue.Queue(maxsize=max(int(depth), 1))
    stop = threading.Event()

    def _put(item) -> bool:
        """Bounded put that gives up when the consumer is gone, so an
        abandoned generator (consumer raised mid-run) never leaves the
        worker blocked forever holding staged device cohorts."""
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def worker():
        try:
            for sel in sels:
                if stop.is_set():
                    return
                cx, cy = source.cohort(sel)
                if not _put((put(cx), put(cy))):
                    return
        except BaseException as e:      # surfaced on the consumer side
            _put(_PrefetchError(e))
            return
        _put(_STOP)

    threading.Thread(target=worker, daemon=True,
                     name="cohort-prefetch").start()
    try:
        while True:
            item = q.get()
            if item is _STOP:
                return
            if isinstance(item, _PrefetchError):
                raise item.exc
            yield item
    finally:
        stop.set()      # unblock + drain the worker on early exit
        while not q.empty():
            try:
                q.get_nowait()
            except queue.Empty:
                break
