from repro.data.loader import epoch_batches, sample_batch
from repro.data.synthetic import (make_federated_classification,
                                  make_lm_sequences, make_prototypes)

__all__ = ["epoch_batches", "sample_batch", "make_federated_classification",
           "make_lm_sequences", "make_prototypes"]
