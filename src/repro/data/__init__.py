from repro.data.loader import (ArraySource, ClientFnSource, CohortSource,
                               as_cohort_source, epoch_batches,
                               prefetch_cohorts, sample_batch)
from repro.data.synthetic import (make_federated_classification,
                                  make_lm_sequences,
                                  make_population_source, make_prototypes)

__all__ = ["ArraySource", "ClientFnSource", "CohortSource",
           "as_cohort_source", "epoch_batches", "prefetch_cohorts",
           "sample_batch", "make_federated_classification",
           "make_lm_sequences", "make_population_source",
           "make_prototypes"]
