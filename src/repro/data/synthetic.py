"""Synthetic federated datasets.

The paper evaluates on CIFAR-10 (IID split over 1000 devices) and FEMNIST
(naturally non-IID). Offline we generate *learnable* synthetic stand-ins:
class-prototype images + Gaussian noise, partitioned IID or with Dirichlet
label skew (the standard non-IID FL protocol). Trends — not absolute
accuracies — are the reproduction target (DESIGN.md §2).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def make_prototypes(key, num_classes: int, image_shape, scale: float = 1.0):
    return scale * jax.random.normal(
        key, (num_classes,) + tuple(image_shape), jnp.float32)


def make_federated_classification(
        key, *, n_clients: int, per_client: int, num_classes: int = 10,
        image_shape=(1, 8, 8), noise: float = 0.6, alpha: float = None):
    """Returns (x (N, n, C, H, W), y (N, n), test_x, test_y).

    alpha=None -> IID label draw; else Dirichlet(alpha) label skew per client.
    """
    kp, kl, kn, kt = jax.random.split(key, 4)
    protos = make_prototypes(kp, num_classes, image_shape)

    if alpha is None:
        y = jax.random.randint(kl, (n_clients, per_client), 0, num_classes)
    else:
        # per-client class distribution ~ Dirichlet(alpha)
        probs = jax.random.dirichlet(
            kl, alpha * jnp.ones((num_classes,)), (n_clients,))
        y = jax.vmap(lambda k, p: jax.random.choice(
            k, num_classes, (per_client,), p=p))(
                jax.random.split(kl, n_clients), probs)

    x = protos[y] + noise * jax.random.normal(
        kn, (n_clients, per_client) + tuple(image_shape))

    n_test = max(num_classes * 20, 200)
    yt = jax.random.randint(kt, (n_test,), 0, num_classes)
    xt = protos[yt] + noise * jax.random.normal(
        jax.random.fold_in(kt, 1), (n_test,) + tuple(image_shape))
    return x, y, xt, yt


def make_population_source(key, *, n_clients: int, per_client: int,
                           num_classes: int = 10, image_shape=(1, 8, 8),
                           noise: float = 0.6):
    """Population-scale synthetic federation (DESIGN.md §10): client i's
    samples are generated ON DEMAND from ``fold_in(key, i)`` — the same
    class-prototype + Gaussian-noise family as
    :func:`make_federated_classification`, but no ``(n, samples, ...)``
    tensor ever exists, so n can be 100_000+ (Alg. 2 line 2 at the
    population sizes Thm 2's r/n amplification targets).

    Returns ``(source, test_x, test_y)`` where ``source`` is a
    :class:`repro.data.loader.ClientFnSource` whose ``cohort(sel)`` is a
    jitted vmap over the selected client ids — O(r) memory per call.
    Deterministic in the client id: the same client always serves the
    same samples, whichever rounds sample it.
    """
    from repro.data import loader

    kp, kc, kt = jax.random.split(key, 3)
    protos = make_prototypes(kp, num_classes, image_shape)
    shape = tuple(image_shape)

    def one_client(cid):
        ck = jax.random.fold_in(kc, cid)
        kl, kn = jax.random.split(ck)
        y = jax.random.randint(kl, (per_client,), 0, num_classes)
        x = protos[y] + noise * jax.random.normal(
            kn, (per_client,) + shape)
        return x, y

    cohort_fn = jax.jit(jax.vmap(one_client))

    def cohort(sel):
        cx, cy = cohort_fn(jnp.asarray(sel))
        return cx, cy

    n_test = max(num_classes * 20, 200)
    yt = jax.random.randint(kt, (n_test,), 0, num_classes)
    xt = protos[yt] + noise * jax.random.normal(
        jax.random.fold_in(kt, 1), (n_test,) + shape)
    return loader.ClientFnSource(cohort, n_clients), xt, yt


def make_lm_sequences(key, *, n_seqs: int, seq_len: int, vocab: int,
                      order: int = 1):
    """Synthetic LM data from a random Markov chain (learnable structure)."""
    kt, ks, k0 = jax.random.split(key, 3)
    logits = 2.0 * jax.random.normal(kt, (vocab, vocab))

    def gen(key):
        k0, kseq = jax.random.split(key)
        first = jax.random.randint(k0, (), 0, vocab)

        def step(tok, k):
            nxt = jax.random.categorical(k, logits[tok])
            return nxt, nxt

        _, toks = jax.lax.scan(step, first,
                               jax.random.split(kseq, seq_len - 1))
        return jnp.concatenate([first[None], toks])

    seqs = jax.vmap(gen)(jax.random.split(ks, n_seqs))
    return seqs.astype(jnp.int32)
