"""Logical-axis -> mesh-axis sharding rules.

Params carry *logical* axis names; this module resolves them against a mesh.

Conventions (see DESIGN.md §6):
  - "fsdp"    -> the `data` mesh axis (params sharded for memory)
  - "tensor"  -> the `model` mesh axis (heads / ff / experts / vocab)
  - "batch"   -> (`pod`, `data`) for activations
  - params are REPLICATED over `pod` (each pod = one FL client)
  - a logical axis resolves to None (replicated) if the tensor dim is not
    divisible by the mesh axis size — this is how small archs (whisper-tiny,
    mamba2-130m heads) degrade gracefully instead of failing to lower.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


LOGICAL_TO_MESH = {
    "fsdp": "data",
    "tensor": "model",
    "clients": "pod",       # explicit client (FL) dim of param replicas
    "cohort": ("pod", "data"),  # FL-round client dim of (r, d) update
                                # batches under sharded cohort execution
                                # (DESIGN.md §7) — one client per mesh slot
    "batch": ("pod", "data"),
    "batch_nopod": "data",
    "seq_mp": "model",      # sequence dim sharded over model (context parallel)
    "seq_all": ("data", "model"),
    "layers": None,
    None: None,
}


def cohort_axis_size(mesh: Mesh) -> int:
    """Extent of the FL-cohort client dim on `mesh` (the ('pod','data')
    product) — how many shards the round's r clients split into."""
    return mesh_axis_size(mesh, LOGICAL_TO_MESH["cohort"])


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(axis, 1)


_EXCLUDED = threading.local()
_OVERRIDES = threading.local()


@contextlib.contextmanager
def logical_overrides(mapping):
    """Re-map logical axes for a region — e.g. pure-FSDP parallelism maps
    'tensor'->None and folds the `model` axis into batch/fsdp."""
    prev = getattr(_OVERRIDES, "map", None)
    _OVERRIDES.map = dict(mapping)
    try:
        yield
    finally:
        _OVERRIDES.map = prev


PURE_FSDP = {
    "batch": ("pod", "data", "model"),
    "batch_nopod": ("data", "model"),
    "fsdp": ("data", "model"),
    "tensor": None,
    "seq_mp": None,
    "seq_all": ("data", "model"),
}


@contextlib.contextmanager
def exclude_axes(*axes):
    """Constraints inside this context never reference `axes` — required
    inside vmap(spmd_axis_name=...) regions and shard_map manual regions."""
    prev = getattr(_EXCLUDED, "axes", frozenset())
    _EXCLUDED.axes = prev | frozenset(axes)
    try:
        yield
    finally:
        _EXCLUDED.axes = prev


def _usable_axes(mesh: Mesh):
    """Mesh axes that constraints may reference: present, not Manual
    (inside a shard_map manual region), and not excluded (inside a
    vmap(spmd_axis_name=...) region)."""
    types = getattr(mesh, "_name_to_type", None)
    excluded = getattr(_EXCLUDED, "axes", frozenset())
    usable = set()
    for a in mesh.shape:
        if a in excluded:
            continue
        if types is not None and "Manual" in str(types.get(a, "")):
            continue
        usable.add(a)
    return usable


def resolve_spec(logical: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh) -> P:
    """Resolve logical axis names to a PartitionSpec, dropping axes whose size
    does not divide the tensor dim (graceful replication)."""
    usable = _usable_axes(mesh)
    overrides = getattr(_OVERRIDES, "map", None)
    out = []
    for name, dim in zip(logical, shape):
        if overrides is not None and name in overrides:
            axis = overrides[name]
        else:
            axis = LOGICAL_TO_MESH.get(name, None)
        # drop mesh axes missing from this mesh (e.g. 'pod' on single pod)
        # or manual inside a shard_map region
        if isinstance(axis, tuple):
            axis = tuple(a for a in axis if a in usable)
            if not axis:
                axis = None
            elif len(axis) == 1:
                axis = axis[0]
        elif axis is not None and axis not in usable:
            axis = None
        if axis is not None and dim % mesh_axis_size(mesh, axis) != 0:
            axis = None
        out.append(axis)
    # trailing Nones can be dropped but keeping them is harmless
    return P(*out)


def named_sharding(mesh: Mesh, logical: Sequence[Optional[str]],
                   shape: Sequence[int]) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical, shape, mesh))


def tree_shardings(mesh: Mesh, logical_tree, shape_tree):
    """Map a pytree of logical-axis tuples + matching ShapeDtypeStructs to
    NamedShardings."""
    return jax.tree.map(
        lambda lg, sd: named_sharding(mesh, lg, sd.shape),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def constraint(x, *logical):
    """with_sharding_constraint against the ambient mesh, dropping
    non-divisible axes. Usable inside jit bodies."""
    mesh = get_abstract_mesh_or_none()
    if mesh is None:
        return x
    spec = resolve_spec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def get_abstract_mesh_or_none():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if m is None or not m.shape:
        return None
    return m
