from repro.sharding.rules import (constraint, named_sharding, resolve_spec,
                                  tree_shardings)

__all__ = ["constraint", "named_sharding", "resolve_spec", "tree_shardings"]
