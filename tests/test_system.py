"""End-to-end behaviour tests for the paper's system.

Validates the paper's principal empirical claims at CPU scale:
  1. PFELS trains to useful accuracy under a fixed per-round DP budget.
  2. PFELS uses fewer subcarriers (communication) than the full-update
     baselines (Table 2/3).
  3. PFELS consumes less transmit energy than WFL-P (Fig. 7).
  4. The production (pod-client) train step runs numerically and the
     PFELS transform keeps the model finite.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs import PFELSConfig, reduced_config
from repro.configs.paper_models import BENCH_MLP
from repro.data import make_federated_classification
from repro.fl import evaluate, make_round_fn, setup
from repro.models import cnn, transformer as T

pytestmark = pytest.mark.slow  # multi-round training / production steps


@pytest.fixture(scope="module")
def fl_setting():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    x, y, xt, yt = make_federated_classification(
        key, n_clients=40, per_client=40, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, flat.shape[0], unravel, (x, y, xt, yt), loss_fn


def _run(alg, fl_setting, rounds=20, p=0.3, eps=2.0, seed=11):
    params, d, unravel, (x, y, xt, yt), loss_fn = fl_setting
    cfg = PFELSConfig(num_clients=40, clients_per_round=8, local_steps=5,
                      local_lr=0.05, compression_ratio=p, epsilon=eps,
                      rounds=rounds, momentum=0.9, algorithm=alg)
    state = setup(jax.random.PRNGKey(1), params, cfg, d)
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    pm = params
    energy, subc = 0.0, 0
    for t in range(rounds):
        pm, m = fn(pm, state.power_limits, x, y,
                   jax.random.PRNGKey(seed * 1000 + t))
        energy += float(m["energy"])
        subc = int(m["subcarriers"])
    _, acc = evaluate(pm, loss_fn, xt, yt)
    return acc, energy, subc


def test_pfels_trains_under_dp(fl_setting):
    acc, energy, subc = _run("pfels", fl_setting)
    assert acc > 0.45
    assert energy > 0


def test_pfels_fewer_subcarriers_than_baselines(fl_setting):
    _, _, sub_pfels = _run("pfels", fl_setting, rounds=2)
    _, _, sub_wflp = _run("wfl_p", fl_setting, rounds=2)
    d = fl_setting[1]
    assert sub_pfels == int(round(0.3 * d))
    assert sub_wflp == d
    assert sub_pfels < sub_wflp


def test_pfels_energy_below_wfl_p(fl_setting):
    """Fig. 7: PFELS transmits k < d coordinates -> lower energy than WFL-P
    at the same number of rounds (statistically; fixed seeds here)."""
    _, e_pfels, _ = _run("pfels", fl_setting, rounds=6, seed=3)
    _, e_wflp, _ = _run("wfl_p", fl_setting, rounds=6, seed=3)
    assert e_pfels < e_wflp


def test_production_step_numerics():
    """The pod-scale PFELS train step (single-client path) on a reduced
    arch: params stay finite and loss is reasonable."""
    from repro.launch.mesh import make_host_mesh, use_mesh
    from repro.launch.steps import make_pfels_train_step
    cfg = reduced_config("phi3-mini-3.8b")
    mesh = make_host_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(key, cfg)
    d = sum(x.size for x in jax.tree.leaves(params))
    pfels = PFELSConfig(num_clients=100, clients_per_round=1,
                        compression_ratio=0.3, epsilon=2.0, local_lr=0.05,
                        local_steps=1)
    step = make_pfels_train_step(cfg, pfels, d, mesh)
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
    }
    with use_mesh(mesh):
        step_j = jax.jit(step)
        p2, m = step_j(params, batch, jax.random.fold_in(key, 1))
        p3, m2 = step_j(p2, batch, jax.random.fold_in(key, 2))
    assert jnp.isfinite(m["loss"]) and jnp.isfinite(m2["loss"])
    assert float(m["energy"]) > 0
    assert not any(bool(jnp.any(jnp.isnan(x))) for x in jax.tree.leaves(p3))


def test_production_grad_accum_equivalence():
    """grad_accum=2 gives the same update direction as accum=1 (same data,
    sigma0~0, p=1 so masking is dense)."""
    from repro.launch.mesh import make_host_mesh, use_mesh
    from repro.launch.steps import make_pfels_train_step
    from repro.configs.base import ChannelConfig
    cfg = dataclasses.replace(reduced_config("mamba2-130m"),
                              dtype="float32", param_dtype="float32")
    mesh = make_host_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(key, cfg)
    d = sum(x.size for x in jax.tree.leaves(params))
    chan = ChannelConfig(noise_std=1e-9)
    base = dict(num_clients=100, clients_per_round=1, compression_ratio=1.0,
                epsilon=1e9, local_lr=0.05, local_steps=1, channel=chan)
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
    }
    outs = []
    with use_mesh(mesh):
        for accum in (1, 2):
            pf = PFELSConfig(grad_accum=accum, **base)
            step = jax.jit(make_pfels_train_step(cfg, pf, d, mesh))
            p2, m = step(params, batch, key)
            outs.append(ravel_pytree(p2)[0])
    diff = float(jnp.max(jnp.abs(outs[0] - outs[1])))
    assert diff < 5e-3, diff


def test_production_tau_local_steps():
    """tau > 1 production step (Alg. 2 lines 6-10 at pod scale): runs,
    stays finite, and the local update differs from the tau=1 gradient
    step (multiple sequential SGD steps)."""
    from repro.launch.mesh import make_host_mesh, use_mesh
    from repro.launch.steps import make_pfels_train_step
    from repro.configs.base import ChannelConfig
    cfg = dataclasses.replace(reduced_config("phi3-mini-3.8b"),
                              dtype="float32", param_dtype="float32")
    mesh = make_host_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(key, cfg)
    d = sum(x.size for x in jax.tree.leaves(params))
    chan = ChannelConfig(noise_std=1e-9)
    batch = {
        "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
    }
    outs = []
    with use_mesh(mesh):
        for tau in (1, 4):
            pf = PFELSConfig(num_clients=100, clients_per_round=1,
                             compression_ratio=1.0, epsilon=1e9,
                             local_lr=0.05, local_steps=tau, channel=chan)
            step = jax.jit(make_pfels_train_step(cfg, pf, d, mesh))
            p2, m = step(params, batch, key)
            assert jnp.isfinite(m["loss"])
            outs.append(ravel_pytree(p2)[0])
    diff = float(jnp.max(jnp.abs(outs[0] - outs[1])))
    assert diff > 1e-6  # tau=4 takes a different (multi-step) trajectory
