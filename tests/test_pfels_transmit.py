"""Fused PFELS transmit pipeline: Pallas kernel == ref.py == the unfused
aircomp_aggregate path (same PRNG key => bit-identical noise draw), across
odd d, k=1, k=d, r=1 edge cases; plus the round-level wiring behind
cfg.use_fused_kernel and the lax.scan multi-round driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs import PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.core import aggregation, randk
from repro.data import make_federated_classification
from repro.fl import make_round_fn, make_training_fn, setup
from repro.kernels.pfels_transmit import ref as tref
from repro.kernels.pfels_transmit.ops import fused_transmit
from repro.models import cnn

CASES = [
    (3, 40, 10),     # generic
    (1, 37, 1),      # r=1, odd d, k=1
    (4, 37, 37),     # k=d, odd d
    (2, 128, 64),    # lane-aligned d
    (5, 301, 17),    # odd everything
]


def _problem(r, d, k, seed=0):
    key = jax.random.PRNGKey(seed)
    updates = jax.random.normal(key, (r, d))
    gains = (jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (r,)))
             * 0.05 + 0.01)
    idx = randk.sample_indices(jax.random.fold_in(key, 2), d, k)
    noise_key = jax.random.fold_in(key, 3)
    return updates, gains, idx, noise_key


@pytest.mark.parametrize("r,d,k", CASES)
@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jax_ref"])
def test_fused_matches_unfused(r, d, k, use_kernel):
    updates, gains, idx, nk = _problem(r, d, k)
    beta, sigma0 = 0.7, 0.3
    dh0, e0, y0 = aggregation.aircomp_aggregate(
        updates, idx, gains, beta, nk, d=d, sigma0=sigma0, r=r)
    dh1, e1, y1 = fused_transmit(
        updates, idx, gains, beta, nk, d=d, sigma0=sigma0, r=r,
        use_kernel=use_kernel)
    np.testing.assert_allclose(dh1, dh0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e0, rtol=1e-5)
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-6)


def test_noise_draw_bit_identical():
    """Same PRNG key => the fused path consumes the exact same channel-noise
    realization as the unfused path: with the superposition zeroed out
    (zero updates) the received payloads agree bit-for-bit."""
    r, d, k = 3, 64, 16
    _, gains, idx, nk = _problem(r, d, k)
    zeros = jnp.zeros((r, d))
    _, _, y0 = aggregation.aircomp_aggregate(
        zeros, idx, gains, 1.0, nk, d=d, sigma0=0.9, r=r)
    for use_kernel in (True, False):
        _, _, y1 = fused_transmit(zeros, idx, gains, 1.0, nk, d=d,
                                  sigma0=0.9, r=r, use_kernel=use_kernel)
        assert bool(jnp.all(y0 == y1))


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jax_ref"])
def test_fused_clip_matches_preclipped_unfused(use_kernel):
    """transmit_clip == pre-clipping the updates then running unfused."""
    r, d, k = 4, 50, 20
    updates, gains, idx, nk = _problem(r, d, k, seed=7)
    updates = 3.0 * updates
    clip, beta, sigma0 = 1.5, 0.9, 0.2
    norms = jnp.linalg.norm(updates, axis=1, keepdims=True)
    pre = updates * jnp.minimum(1.0, clip / norms)
    dh0, e0, y0 = aggregation.aircomp_aggregate(
        pre, idx, gains, beta, nk, d=d, sigma0=sigma0, r=r)
    dh1, e1, y1 = fused_transmit(
        updates, idx, gains, beta, nk, d=d, sigma0=sigma0, r=r, clip=clip,
        use_kernel=use_kernel)
    np.testing.assert_allclose(dh1, dh0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e0, rtol=1e-5)
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-6)


def test_unfused_clip_arg_matches_manual():
    """The new clip= arg on aircomp_aggregate == manual pre-clip."""
    r, d, k = 3, 30, 9
    updates, gains, idx, nk = _problem(r, d, k, seed=9)
    updates = 5.0 * updates
    norms = jnp.linalg.norm(updates, axis=1, keepdims=True)
    pre = updates * jnp.minimum(1.0, 2.0 / norms)
    a = aggregation.aircomp_aggregate(pre, idx, gains, 1.1, nk, d=d,
                                      sigma0=0.1, r=r)
    b = aggregation.aircomp_aggregate(updates, idx, gains, 1.1, nk, d=d,
                                      sigma0=0.1, r=r, clip=2.0)
    for x, y in zip(a, b):
        np.testing.assert_allclose(y, x, rtol=1e-6)


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jax_ref"])
def test_fused_imperfect_csi_and_rescale(use_kernel):
    """gains_est precompensation and unbiased_rescale flow through fused."""
    r, d, k = 4, 45, 15
    updates, gains, idx, nk = _problem(r, d, k, seed=3)
    gains_est = gains * 1.07
    kw = dict(d=d, sigma0=0.25, r=r, gains_est=gains_est,
              unbiased_rescale=True)
    dh0, e0, y0 = aggregation.aircomp_aggregate(
        updates, idx, gains, 0.8, nk, **kw)
    dh1, e1, y1 = fused_transmit(updates, idx, gains, 0.8, nk,
                                 use_kernel=use_kernel, **kw)
    np.testing.assert_allclose(dh1, dh0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e0, rtol=1e-5)
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-6)


def test_client_sumsq_kernel_matches_ref():
    """Pass-1 Pallas reduction == the ref.py sumsq oracle (zero column
    padding is norm-neutral)."""
    from repro.kernels.pfels_transmit.kernel import client_sumsq
    r, d = 5, 300
    u = jax.random.normal(jax.random.PRNGKey(13), (r, d))
    u_pad = jnp.pad(u, ((0, 0), (0, 512 - d)))
    out = client_sumsq(u_pad, block=128, interpret=True)
    np.testing.assert_allclose(out[:, 0], tref.client_sumsq_ref(u),
                               rtol=1e-6)


def test_kernel_matches_ref_module():
    """Pallas kernel == the ref.py oracle on the dense formulation."""
    r, d = 3, 200
    key = jax.random.PRNGKey(11)
    u = jax.random.normal(key, (r, d))
    idx = randk.sample_indices(key, d, 60)
    mask = jnp.zeros((d,)).at[idx].set(1.0)
    z = jnp.zeros((d,)).at[idx].set(0.1)
    scales = tref.clip_scales(u, 1.0)
    tx, rx = tref.transmit_coeffs(jnp.full((r,), 0.05), 0.9, scales)
    y_ref, e_ref = tref.pfels_transmit_ref(u, mask, z, rx, tx ** 2)
    dh_k, e_k, _ = fused_transmit(u, idx, jnp.full((r,), 0.05), 0.9,
                                  jax.random.PRNGKey(0), d=d, sigma0=0.0,
                                  r=r, clip=1.0, use_kernel=True)
    # sigma0=0 => z contribution differs; compare the noiseless parts
    dh_ref = (y_ref - z) / (r * 0.9)
    np.testing.assert_allclose(dh_k, dh_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e_k, e_ref, rtol=1e-5)


# ------------------------------------- fused-default scenario sweep (PR 6)
# use_fused_kernel=True is the DEFAULT execution mode for every registered
# channel model on both execution paths; these properties pin fused ==
# unfused-oracle fp32 parity for the in-tile transmit mask (dropout), the
# in-tile MRC combine (gains_ant matrix), and their interaction with
# clip / imperfect CSI — plus the all-dropped-round realized-r floor.

def _scenario_problem(r, d, k, *, M=None, dropped=0, seed=5):
    key = jax.random.PRNGKey(seed)
    updates = jax.random.normal(key, (r, d))
    if M is not None:
        gains_ant = (jnp.abs(jax.random.normal(
            jax.random.fold_in(key, 1), (r, M))) * 0.05 + 0.01)
        gains = jnp.sum(gains_ant, axis=1)       # the effective MRC view
    else:
        gains_ant = None
        gains = (jnp.abs(jax.random.normal(jax.random.fold_in(key, 1),
                                           (r,))) * 0.05 + 0.01)
    tx_mask = None
    if dropped:
        tx_mask = jnp.ones((r,)).at[
            jnp.arange(dropped)].set(0.0).astype(jnp.float32)
    idx = randk.sample_indices(jax.random.fold_in(key, 2), d, k)
    nk = jax.random.fold_in(key, 3)
    return updates, gains, gains_ant, tx_mask, idx, nk


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jax_ref"])
def test_fused_tx_mask_matches_unfused(use_kernel):
    """In-tile masking (per-client coefficient fold) == the oracle's
    (r, d) pre-mask + realized-r unscale."""
    r, d, k = 5, 80, 24
    updates, gains, _, tx_mask, idx, nk = _scenario_problem(
        r, d, k, dropped=2)
    kw = dict(d=d, sigma0=0.3, r=r, tx_mask=tx_mask)
    dh0, e0, y0 = aggregation.aircomp_aggregate(
        updates, idx, gains, 0.8, nk, **kw)
    dh1, e1, y1 = aggregation.aircomp_aggregate_fused(
        updates, idx, gains, 0.8, nk, use_kernel=use_kernel, **kw)
    np.testing.assert_allclose(dh1, dh0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e0, rtol=1e-5)
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jax_ref"])
def test_fused_mrc_gains_matrix_matches_effective(use_kernel):
    """The kernel's in-tile all-ones-beam combine over a (r, M) gains_ant
    matrix == the oracle on the pre-combined effective gains, and == the
    fused path fed the effective (r,) view directly."""
    r, d, k, M = 4, 70, 21, 4
    updates, gains, gains_ant, _, idx, nk = _scenario_problem(
        r, d, k, M=M)
    kw = dict(d=d, sigma0=0.25, r=r)
    dh0, e0, y0 = aggregation.aircomp_aggregate(
        updates, idx, gains, 0.9, nk, **kw)
    dh1, e1, y1 = aggregation.aircomp_aggregate_fused(
        updates, idx, gains, 0.9, nk, gains_ant=gains_ant,
        use_kernel=use_kernel, **kw)
    dh2, e2, _ = aggregation.aircomp_aggregate_fused(
        updates, idx, gains, 0.9, nk, use_kernel=use_kernel, **kw)
    np.testing.assert_allclose(dh1, dh0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e0, rtol=1e-5)
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dh1, dh2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e2, rtol=1e-5)


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jax_ref"])
def test_fused_mask_mrc_csi_clip_combined(use_kernel):
    """Everything at once: (r, M) gains, transmit mask, transmit clip,
    imperfect-CSI precompensation and unbiased rescale."""
    r, d, k, M = 6, 90, 30, 3
    updates, gains, gains_ant, tx_mask, idx, nk = _scenario_problem(
        r, d, k, M=M, dropped=2, seed=11)
    updates = 3.0 * updates
    kw = dict(d=d, sigma0=0.2, r=r, tx_mask=tx_mask, clip=1.0,
              gains_est=gains * 1.07, unbiased_rescale=True)
    dh0, e0, y0 = aggregation.aircomp_aggregate(
        updates, idx, gains, 0.7, nk, **kw)
    dh1, e1, y1 = aggregation.aircomp_aggregate_fused(
        updates, idx, gains, 0.7, nk, gains_ant=gains_ant,
        use_kernel=use_kernel, **kw)
    np.testing.assert_allclose(dh1, dh0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e0, rtol=1e-5)
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jax_ref"])
def test_fused_all_dropped_round_is_finite(use_kernel):
    """tx_mask all zero: the realized-r floor (max(sum mask, 1)) keeps the
    reconstruction finite — delta_hat is exactly noise/beta on the
    support, energy is exactly zero — on oracle and fused paths alike."""
    r, d, k = 4, 60, 15
    updates, gains, _, _, idx, nk = _scenario_problem(r, d, k)
    tx_mask = jnp.zeros((r,), jnp.float32)
    kw = dict(d=d, sigma0=0.5, r=r, tx_mask=tx_mask)
    dh0, e0, _ = aggregation.aircomp_aggregate(
        updates, idx, gains, 0.8, nk, **kw)
    dh1, e1, _ = aggregation.aircomp_aggregate_fused(
        updates, idx, gains, 0.8, nk, use_kernel=use_kernel, **kw)
    for dh, e in ((dh0, e0), (dh1, e1)):
        assert bool(jnp.all(jnp.isfinite(dh)))
        np.testing.assert_allclose(e, 0.0, atol=1e-12)
    np.testing.assert_allclose(dh1, dh0, rtol=1e-6, atol=1e-7)
    # floor divisor is 1, so the support carries the raw noise over beta
    _, z = tref.dense_noise_and_mask(idx, nk, 0.5, d)
    np.testing.assert_allclose(np.asarray(dh0)[np.asarray(idx)],
                               np.asarray(z)[np.asarray(idx)] / 0.8,
                               rtol=1e-5, atol=1e-7)


def test_realized_r_floor():
    assert aggregation.realized_r(None, 7) == 7
    assert float(aggregation.realized_r(jnp.zeros((5,)), 5)) == 1.0
    assert float(aggregation.realized_r(
        jnp.array([1.0, 0.0, 1.0]), 3)) == 2.0


_SCENARIO_KW = {"markov_fading": dict(markov_rho=0.9),
                "mimo_mrc": dict(num_antennas=4),
                "dropout": dict(dropout_prob=0.4)}
_VARIANTS = {"default": {},
             "ef_clip": dict(error_feedback=True, transmit_clip=0.5),
             "csi": {}}  # csi flips channel.csi_error below


def _channel_model_names():
    from repro.core.channels import list_channel_models
    return list_channel_models()


@pytest.mark.slow
@pytest.mark.parametrize("variant", sorted(_VARIANTS))
@pytest.mark.parametrize("model", _channel_model_names())
def test_fused_default_round_parity_all_models(problem, model, variant):
    """Trainer-level sweep: for EVERY registered channel model, the
    fused-default round == the unfused escape hatch to fp32 tolerance,
    under error feedback + transmit clip and under imperfect CSI —
    2 Trainer.run rounds, same keys."""
    from repro.configs import ChannelConfig
    from repro.fl import Trainer
    from repro.fl.api import replace as st_replace

    params, d, unravel, (x, y), loss_fn = problem
    chan_kw = dict(_SCENARIO_KW.get(model, {}))
    if variant == "csi":
        chan_kw["csi_error"] = 0.1
    outs = []
    for fused in (True, False):
        cfg = PFELSConfig(num_clients=30, clients_per_round=4,
                          local_steps=2, rounds=2, use_fused_kernel=fused,
                          **_VARIANTS[variant])
        import dataclasses
        cfg = dataclasses.replace(
            cfg, channel=ChannelConfig(model=model, **chan_kw))
        trainer = Trainer(cfg, loss_fn, params)
        state = st_replace(trainer.init(jax.random.PRNGKey(1)),
                           key=jax.random.PRNGKey(2))
        outs.append(trainer.run(state, x, y, rounds=2))
    (s1, m1), (s0, m0) = outs
    flat1 = ravel_pytree(s1.params)[0]
    flat0 = ravel_pytree(s0.params)[0]
    np.testing.assert_allclose(flat1, flat0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1["energy"]),
                               np.asarray(m0["energy"]), rtol=1e-4,
                               atol=1e-8)
    np.testing.assert_allclose(np.asarray(m1["beta"]),
                               np.asarray(m0["beta"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m1["eps_round"]),
                               np.asarray(m0["eps_round"]), rtol=1e-5)


needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 host devices (CI runs the fast tier on 8)")


@needs_devices
def test_sharded_fused_mask_and_mrc_matches_global_oracle():
    """aircomp_aggregate_sharded(use_kernel=True) with a transmit mask
    AND a (r_local, M) per-antenna gains shard == the single-device
    unfused oracle on the full cohort — the psum path of the fused
    default."""
    import numpy as onp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.launch.mesh import shard_map_compat

    n_dev = len(jax.devices())
    r, d, k, M = n_dev, 96, 32, 4
    updates, gains, gains_ant, tx_mask, idx, nk = _scenario_problem(
        r, d, k, M=M, dropped=max(1, r // 4), seed=21)
    kw = dict(d=d, sigma0=0.3, r=r)
    dh0, e0, y0 = aggregation.aircomp_aggregate(
        updates, idx, gains, 0.8, nk, tx_mask=tx_mask, **kw)

    mesh = Mesh(onp.asarray(jax.devices()), ("c",))
    fn = shard_map_compat(
        lambda u, g, m: aggregation.aircomp_aggregate_sharded(
            u, idx, g, 0.8, nk, axis_name="c", use_kernel=True,
            tx_mask_local=m, **kw),
        mesh=mesh, in_specs=(P("c"), P("c"), P("c")),
        out_specs=(P(), P(), P()))
    dh1, e1, y1 = fn(updates, gains_ant, tx_mask)
    np.testing.assert_allclose(dh1, dh0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e0, rtol=1e-5)
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- round-level wiring

@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    x, y, xt, yt = make_federated_classification(
        key, n_clients=30, per_client=30, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, flat.shape[0], unravel, (x, y), loss_fn


def test_round_fused_flag_parity(problem):
    """make_round_fn(use_fused_kernel=True) == the unfused round, same key."""
    params, d, unravel, (x, y), loss_fn = problem
    outs = []
    for fused in (False, True):
        cfg = PFELSConfig(num_clients=30, clients_per_round=4,
                          local_steps=3, rounds=1, use_fused_kernel=fused)
        st = setup(jax.random.PRNGKey(1), params, cfg, d)
        fn = make_round_fn(cfg, loss_fn, d, unravel)
        outs.append(fn(params, st.power_limits, x, y, jax.random.PRNGKey(2)))
    (p0, m0), (p1, m1) = outs
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m1["energy"], m0["energy"], rtol=1e-5)
    np.testing.assert_allclose(m1["beta"], m0["beta"], rtol=1e-6)


def test_training_fn_matches_python_loop(problem):
    """The lax.scan driver reproduces T sequential make_round_fn calls when
    fed the same per-round keys."""
    params, d, unravel, (x, y), loss_fn = problem
    cfg = PFELSConfig(num_clients=30, clients_per_round=4, local_steps=2,
                      rounds=3)
    st = setup(jax.random.PRNGKey(1), params, cfg, d)
    T = 3
    tf = make_training_fn(cfg, loss_fn, d, unravel, rounds=T)
    pT, ms, _, _ = tf(params, st.power_limits, x, y, jax.random.PRNGKey(7))
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    keys = jax.random.split(jax.random.PRNGKey(7), T)
    p = params
    loop_losses = []
    for t in range(T):
        p, m = fn(p, st.power_limits, x, y, keys[t])
        loop_losses.append(float(m["train_loss"]))
    for a, b in zip(jax.tree.leaves(pT), jax.tree.leaves(p)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    assert ms["train_loss"].shape == (T,)
    np.testing.assert_allclose(np.asarray(ms["train_loss"]), loop_losses,
                               rtol=1e-5)


def test_training_fn_error_feedback_carries_residuals(problem):
    params, d, unravel, (x, y), loss_fn = problem
    cfg = PFELSConfig(num_clients=30, clients_per_round=4, local_steps=2,
                      rounds=2, error_feedback=True)
    st = setup(jax.random.PRNGKey(1), params, cfg, d)
    tf = make_training_fn(cfg, loss_fn, d, unravel, rounds=2)
    pT, ms, res, _ = tf(params, st.power_limits, x, y, jax.random.PRNGKey(8))
    assert res.shape == (30, d)
    assert float(jnp.sum(jnp.abs(res))) > 0        # memory accumulated
    assert not bool(jnp.any(jnp.isnan(res)))


@pytest.mark.parametrize("alg", ["wfl_p", "dp_fedavg", "fedavg"])
def test_training_fn_baselines_run(problem, alg):
    params, d, unravel, (x, y), loss_fn = problem
    cfg = PFELSConfig(num_clients=30, clients_per_round=4, local_steps=2,
                      rounds=2, algorithm=alg)
    st = setup(jax.random.PRNGKey(1), params, cfg, d)
    tf = make_training_fn(cfg, loss_fn, d, unravel, rounds=2)
    pT, ms, _, _ = tf(params, st.power_limits, x, y, jax.random.PRNGKey(4))
    assert bool(jnp.all(jnp.isfinite(ms["train_loss"])))
    assert not any(bool(jnp.any(jnp.isnan(l))) for l in jax.tree.leaves(pT))


def test_training_fn_fused_server_topk(problem):
    """server_topk carries delta_hat through the scan; fused path works."""
    params, d, unravel, (x, y), loss_fn = problem
    cfg = PFELSConfig(num_clients=30, clients_per_round=4, local_steps=2,
                      rounds=3, randk_mode="server_topk",
                      use_fused_kernel=True)
    st = setup(jax.random.PRNGKey(1), params, cfg, d)
    tf = make_training_fn(cfg, loss_fn, d, unravel, rounds=3)
    pT, ms, _, _ = tf(params, st.power_limits, x, y, jax.random.PRNGKey(5))
    assert bool(jnp.all(jnp.isfinite(ms["train_loss"])))


def test_error_feedback_retains_clipped_mass(problem):
    """With transmit_clip ~ 0 nothing is actually transmitted, so the
    error-feedback residual must keep (almost) the whole update — on-idx
    coordinates included — rather than treating the unclipped on-idx mass
    as sent."""
    params, d, unravel, (x, y), loss_fn = problem
    outs = {}
    for clip in (None, 1e-9):
        cfg = PFELSConfig(num_clients=30, clients_per_round=4,
                          local_steps=3, rounds=1, error_feedback=True,
                          transmit_clip=clip)
        st = setup(jax.random.PRNGKey(1), params, cfg, d)
        fn = make_round_fn(cfg, loss_fn, d, unravel)
        _, _, res = fn(params, st.power_limits, x, y,
                       jax.random.PRNGKey(2),
                       residuals=jnp.zeros((30, d), jnp.float32))
        outs[clip] = float(jnp.linalg.norm(res))
    # clipped-to-zero transmission leaves strictly more in the memory than
    # the unclipped round (which really did send the on-idx mass)
    assert outs[1e-9] > outs[None] * 1.1, outs


def test_training_fn_server_topk_cold_start_is_uniform(problem):
    """Round 1 of a cold scan (zero prev_delta) must equal a cold
    make_round_fn call (prev_delta=None) bit-for-bit: top_k over |zeros|
    would otherwise deterministically bias the support to coords 0..k/2."""
    params, d, unravel, (x, y), loss_fn = problem
    cfg = PFELSConfig(num_clients=30, clients_per_round=4, local_steps=2,
                      rounds=1, randk_mode="server_topk")
    st = setup(jax.random.PRNGKey(1), params, cfg, d)
    tf = make_training_fn(cfg, loss_fn, d, unravel, rounds=1)
    p_scan, _, _, _ = tf(params, st.power_limits, x, y,
                         jax.random.PRNGKey(3))
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    k0 = jax.random.split(jax.random.PRNGKey(3), 1)[0]
    p_cold, _ = fn(params, st.power_limits, x, y, k0)
    for a, b in zip(jax.tree.leaves(p_scan), jax.tree.leaves(p_cold)):
        assert bool(jnp.all(a == b))


def test_training_fn_stateful_scan_matches_loop_and_resumes(problem):
    """With server_topk + error feedback: (a) the scan == a python loop
    over make_round_fn threading (residuals, delta_hat) with the same
    keys and the same zero-initialized state; (b) prev_delta= actually
    changes the resumed trajectory (the carried state is consumed, so
    chunked training does not silently reset the top-k support)."""
    params, d, unravel, (x, y), loss_fn = problem
    cfg = PFELSConfig(num_clients=30, clients_per_round=4, local_steps=2,
                      rounds=4, randk_mode="server_topk",
                      error_feedback=True)
    st = setup(jax.random.PRNGKey(1), params, cfg, d)

    tf4 = make_training_fn(cfg, loss_fn, d, unravel, rounds=4)
    p_full, _, res_full, dh_full = tf4(params, st.power_limits, x, y,
                                       jax.random.PRNGKey(6))

    fn = make_round_fn(cfg, loss_fn, d, unravel)
    keys = jax.random.split(jax.random.PRNGKey(6), 4)
    p = params
    res = jnp.zeros((cfg.num_clients, d), jnp.float32)
    dh = jnp.zeros((d,), jnp.float32)
    for t in range(4):
        p, m, res = fn(p, st.power_limits, x, y, keys[t],
                       residuals=res, prev_delta=dh)
        dh = m["delta_hat"]
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dh_full, dh, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(res_full, res, rtol=1e-5, atol=1e-6)

    # (b) resuming with the carried delta differs from a cold restart
    tf2 = make_training_fn(cfg, loss_fn, d, unravel, rounds=2)
    warm, _, _, _ = tf2(p_full, st.power_limits, x, y,
                        jax.random.PRNGKey(8), residuals=res_full,
                        prev_delta=dh_full)
    cold, _, _, _ = tf2(p_full, st.power_limits, x, y,
                        jax.random.PRNGKey(8), residuals=res_full)
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(warm), jax.tree.leaves(cold)))
    assert diff > 0.0  # top-k support came from dh_full, not zeros
