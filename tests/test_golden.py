"""Golden-regression tier (ISSUE 5): fp32 digests of one ``Trainer.run``
per (algorithm × execution path × channel model) are pinned against
``tests/goldens/golden_digests.json`` so no PR can silently move the
numerics of the reproduction. The ``block_fading`` rows were generated
from the PRE-channel-registry tree and verified exact against the
refactor — the bit-identity proof of the extraction. Refresh
intentionally-changed rows with
``PYTHONPATH=src python tools/update_goldens.py --refresh [--only pat]``.
"""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tools"))

import update_goldens

# fp32-computation drift floor: tight enough that a PRNG-lane shift (O(1)
# relative change) or an accumulation-order change (~1e-7 relative on
# these digests) fails, loose enough to absorb vectorization differences
# across CPU generations on the same pinned jax
RTOL = 1e-6

_GOLDEN = update_goldens.load_goldens()
_PROBLEM = None


def _problem():
    global _PROBLEM
    if _PROBLEM is None:
        _PROBLEM = update_goldens._problem()
    return _PROBLEM


def _assert_close(path, got, want):
    if isinstance(want, dict):
        assert set(got) == set(want), path
        for k in want:
            _assert_close(f"{path}.{k}", got[k], want[k])
    elif isinstance(want, list):
        assert len(got) == len(want), path
        for i, (g, w) in enumerate(zip(got, want)):
            _assert_close(f"{path}[{i}]", g, w)
    elif isinstance(want, float):
        assert got == pytest.approx(want, rel=RTOL, abs=1e-12), \
            f"{path}: golden={want!r} got={got!r}"
    else:
        assert got == want, f"{path}: golden={want!r} got={got!r}"


@pytest.mark.parametrize("case", sorted(update_goldens._cases()))
def test_golden_digest(case):
    golden = _GOLDEN["cases"].get(case)
    assert golden is not None, (
        f"no golden for {case}; run tools/update_goldens.py --refresh "
        f"--only '{case}'")
    need = golden["needs_devices"]
    if need > 1 and len(jax.devices()) != need:
        pytest.skip(f"sharded golden generated on {need} devices "
                    f"(CI docs job runs the fast tier on 8)")
    got = update_goldens.run_case(case, _problem())
    _assert_close(case, got, golden)


def test_golden_file_covers_every_case():
    """A new case added to the harness without a checked-in golden must
    fail loudly here, not silently skip — and a renamed/deleted case must
    not leave an orphaned digest that looks pinned but never runs."""
    missing = sorted(set(update_goldens._cases()) - set(_GOLDEN["cases"]))
    assert missing == [], (
        f"run tools/update_goldens.py --refresh --only "
        f"'{','.join(missing)}'")
    stale = sorted(set(_GOLDEN["cases"]) - set(update_goldens._cases()))
    assert stale == [], (
        f"orphaned golden rows {stale}; tools/update_goldens.py "
        f"--refresh prunes them")
