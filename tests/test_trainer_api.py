"""The unified Trainer/TrainState API (DESIGN.md §8): golden parity against
the legacy ``make_round_fn``/``make_training_fn`` shims under identical
keys, in-graph ledger totals vs the host-side ``PrivacyLedger``, uniform
signatures, chunked resume, and algorithm-registry round-trip."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs import PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.core import privacy
from repro.data import make_federated_classification
from repro.fl import (Algorithm, Trainer, make_round_fn, make_training_fn,
                      register_algorithm, round_epsilon_spent, setup,
                      unregister_algorithm)
from repro.fl.api import replace
from repro.launch.mesh import make_cohort_mesh
from repro.models import cnn

MULTI = len(jax.devices()) >= 2
BASE = dict(num_clients=20, clients_per_round=4, local_steps=2,
            local_lr=0.05, compression_ratio=0.3, epsilon=2.0, rounds=2)


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    x, y, xt, yt = make_federated_classification(
        key, n_clients=20, per_client=20, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, flat.shape[0], unravel, (x, y, xt, yt), loss_fn


def _flat(p):
    return ravel_pytree(p)[0]


def _legacy(cfg, problem, mesh=None):
    """(round_fn, training_fn(T=3), legacy FLState) with warnings silenced
    — the shims are deprecated by design and these are the parity tests."""
    params, d, unravel, _, loss_fn = problem
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        fn = make_round_fn(cfg, loss_fn, d, unravel, mesh=mesh)
        tf = make_training_fn(cfg, loss_fn, d, unravel, rounds=3, mesh=mesh)
        st = setup(jax.random.PRNGKey(1), params, cfg, d)
    return fn, tf, st


def _trainer_state(cfg, problem, mesh=None):
    params, d, unravel, _, loss_fn = problem
    trainer = Trainer(cfg, loss_fn, params, mesh=mesh)
    state = replace(trainer.init(jax.random.PRNGKey(1)),
                    key=jax.random.PRNGKey(2))
    return trainer, state


PARITY_CASES = {
    "base": {},
    "error_feedback": dict(error_feedback=True, transmit_clip=0.5),
    "server_topk": dict(randk_mode="server_topk"),
    "fused_kernel": dict(use_fused_kernel=True),
    "wfl_p": dict(algorithm="wfl_p"),
    "wfl_pdp": dict(algorithm="wfl_pdp"),
    "dp_fedavg": dict(algorithm="dp_fedavg"),
    "fedavg": dict(algorithm="fedavg"),
}


@pytest.mark.parametrize("case", sorted(PARITY_CASES))
def test_step_and_run_match_legacy_bitwise(problem, case):
    """Trainer.step == legacy make_round_fn and Trainer.run == legacy
    make_training_fn, bit-for-bit under the same PRNG key, for every
    registered paper algorithm and execution option."""
    cfg = PFELSConfig(**BASE, **PARITY_CASES[case])
    d = problem[1]
    x, y = problem[3][0], problem[3][1]
    fn, tf, legacy_st = _legacy(cfg, problem)
    trainer, state = _trainer_state(cfg, problem)

    # power limits: init(key) draws what setup(key) drew
    assert bool(jnp.array_equal(state.power_limits, legacy_st.power_limits))

    # single round: step consumes state.key exactly like round_fn(key=...)
    out = fn(state.params, legacy_st.power_limits, x, y,
             jax.random.PRNGKey(2), legacy_st.residuals,
             jnp.zeros((d,), jnp.float32))
    new_state, metrics = trainer.step(state, x, y)
    assert bool(jnp.array_equal(_flat(new_state.params), _flat(out[0])))
    for k in ("train_loss", "beta", "energy", "subcarriers"):
        assert bool(jnp.array_equal(metrics[k], out[1][k])), k
    if cfg.error_feedback:
        assert bool(jnp.array_equal(new_state.residuals, out[2]))

    # T rounds: run splits state.key exactly like the legacy scan driver
    pT, mT, resT, deltaT = tf(state.params, legacy_st.power_limits, x, y,
                              jax.random.PRNGKey(2), legacy_st.residuals)
    run_state, run_metrics = trainer.run(state, x, y, rounds=3)
    assert bool(jnp.array_equal(_flat(run_state.params), _flat(pT)))
    assert bool(jnp.array_equal(run_state.prev_delta, deltaT))
    assert bool(jnp.array_equal(run_metrics["train_loss"],
                                mT["train_loss"]))
    if cfg.error_feedback:
        assert bool(jnp.array_equal(run_state.residuals, resT))
    assert int(run_state.round) == 3


@pytest.mark.skipif(not MULTI, reason="needs >= 2 host devices (the CI "
                    "docs job forces 8)")
def test_trainer_matches_legacy_under_cohort_sharding(problem):
    """The sharded cohort path through the Trainer equals the sharded
    legacy path bitwise (both route the identical core)."""
    cfg = PFELSConfig(**BASE, client_sharding="cohort")
    mesh = make_cohort_mesh(cfg.clients_per_round)
    x, y = problem[3][0], problem[3][1]
    fn, _, legacy_st = _legacy(cfg, problem, mesh=mesh)
    trainer, state = _trainer_state(cfg, problem, mesh=mesh)
    pL, _ = fn(state.params, legacy_st.power_limits, x, y,
               jax.random.PRNGKey(2))
    new_state, _ = trainer.step(state, x, y)
    assert bool(jnp.array_equal(_flat(new_state.params), _flat(pL)))


def test_uniform_signature_and_no_metrics_leak(problem):
    """One return shape regardless of config: always (state, metrics), no
    'delta_hat' metrics key, identical metric-key sets across algorithms;
    server_topk support state is explicit TrainState.prev_delta."""
    x, y = problem[3][0], problem[3][1]
    keysets = set()
    for case, extra in PARITY_CASES.items():
        cfg = PFELSConfig(**BASE, **extra)
        trainer, state = _trainer_state(cfg, problem)
        state, metrics = trainer.step(state, x, y)
        assert "delta_hat" not in metrics, case
        keysets.add(frozenset(metrics))
        if extra.get("randk_mode") == "server_topk":
            state, _ = trainer.step(state, x, y)
            k = max(int(round(cfg.compression_ratio * trainer.d)), 1)
            assert int(jnp.sum(state.prev_delta != 0)) <= k
    assert len(keysets) == 1   # the fixed metrics contract


def test_legacy_shims_warn_and_leak_behind_deprecation(problem):
    params, d, unravel, (x, y, _, _), loss_fn = problem
    cfg = PFELSConfig(**BASE, randk_mode="server_topk")
    with pytest.deprecated_call():
        fn = make_round_fn(cfg, loss_fn, d, unravel)
    with pytest.deprecated_call():
        st = setup(jax.random.PRNGKey(1), params, cfg, d)
    _, m = fn(params, st.power_limits, x, y, jax.random.PRNGKey(2))
    assert "delta_hat" in m   # seed-era contract, kept behind the warning


def test_in_graph_ledger_matches_host_ledger(problem):
    """Trainer.run's compiled (eps, delta) accumulators equal the Python
    PrivacyLedger fed the same per-round betas, to fp32 tolerance."""
    params, d, unravel, (x, y, _, _), loss_fn = problem
    for alg in ("pfels", "wfl_pdp"):
        cfg = PFELSConfig(**BASE, **({} if alg == "pfels"
                                     else {"algorithm": alg}))
        trainer, state = _trainer_state(cfg, problem)
        t = 6
        end, metrics = trainer.run(state, x, y, rounds=t)

        host = privacy.PrivacyLedger(n=cfg.num_clients,
                                     delta=cfg.resolved_delta())
        for beta in np.asarray(metrics["beta"]):
            host.spend(min(round_epsilon_spent(cfg, float(beta)),
                           cfg.epsilon))
        totals = trainer.ledger_totals(end)
        np.testing.assert_allclose(totals["basic"], host.total_basic(),
                                   rtol=1e-5)
        np.testing.assert_allclose(totals["advanced"],
                                   host.total_advanced(), rtol=1e-5)
        assert totals["spends"] == t
        # eps_round metric is what the ledger saw, round for round
        np.testing.assert_allclose(np.asarray(metrics["eps_round"]),
                                   host.eps_rounds, rtol=1e-6)


def test_non_dp_algorithms_keep_empty_ledger(problem):
    """wfl_p/fedavg carry no per-round guarantee: the ledger must stay at
    the empty-ledger contract (0.0, 0.0), not accumulate zero-eps rounds."""
    x, y = problem[3][0], problem[3][1]
    for alg in ("wfl_p", "fedavg"):
        cfg = PFELSConfig(**BASE, algorithm=alg)
        trainer, state = _trainer_state(cfg, problem)
        end, _ = trainer.run(state, x, y, rounds=3)
        totals = trainer.ledger_totals(end)
        assert totals["basic"] == (0.0, 0.0)
        assert totals["advanced"] == (0.0, 0.0)
        assert totals["spends"] == 0


def test_chunked_resume_carries_all_state(problem):
    """run(3); run(3) continues the ledger, the round counter, the PRNG
    stream, and the error-feedback memory without host bookkeeping."""
    x, y = problem[3][0], problem[3][1]
    cfg = PFELSConfig(**BASE, error_feedback=True)
    trainer, state = _trainer_state(cfg, problem)
    s1, m1 = trainer.run(state, x, y, rounds=3)
    s2, m2 = trainer.run(s1, x, y, rounds=3)
    assert int(s2.round) == 6
    assert int(s2.ledger.spends) == 6
    np.testing.assert_allclose(
        float(s2.ledger.eps_sum),
        float(jnp.sum(m1["eps_round"]) + jnp.sum(m2["eps_round"])),
        rtol=1e-6)
    assert not bool(jnp.array_equal(s1.key, s2.key))
    assert float(jnp.sum(jnp.abs(s2.residuals))) > 0


def test_trainstate_is_a_pytree(problem):
    """TrainState round-trips jax.tree flatten/unflatten (scan/donate/
    checkpoint safe)."""
    cfg = PFELSConfig(**BASE, error_feedback=True)
    trainer, state = _trainer_state(cfg, problem)
    leaves, treedef = jax.tree.flatten(state)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert bool(jnp.array_equal(_flat(rebuilt.params), _flat(state.params)))
    assert bool(jnp.array_equal(rebuilt.ledger.eps_sum,
                                state.ledger.eps_sum))


def test_registry_round_trip(problem):
    """Registering a toy digital scheme makes it a first-class
    cfg.algorithm value: two Trainer rounds run, params move, the ledger
    stays empty (no privacy_spend hook)."""
    from repro.core import aggregation

    def sign_aggregate(cfg, flat_updates, noise_key, *, d, r):
        return 0.01 * jnp.sign(aggregation.fedavg_aggregate(flat_updates))

    register_algorithm("toy_signsgd", Algorithm(
        name="toy_signsgd", aircomp=False,
        server_aggregate=sign_aggregate))
    try:
        x, y = problem[3][0], problem[3][1]
        cfg = PFELSConfig(**BASE, algorithm="toy_signsgd")
        trainer, state = _trainer_state(cfg, problem)
        end, metrics = trainer.run(state, x, y, rounds=2)
        assert jnp.all(jnp.isfinite(metrics["train_loss"]))
        assert not bool(jnp.array_equal(_flat(end.params),
                                        _flat(state.params)))
        assert trainer.ledger_totals(end)["spends"] == 0
        assert int(metrics["subcarriers"][0]) == trainer.d
    finally:
        unregister_algorithm("toy_signsgd")


def test_registry_validation():
    with pytest.raises(KeyError, match="unknown algorithm"):
        from repro.fl import get_algorithm
        get_algorithm("no_such_scheme")
    with pytest.raises(ValueError, match="already registered"):
        register_algorithm("pfels", Algorithm(
            name="pfels", aircomp=False, server_aggregate=lambda *a, **k: 0))
    with pytest.raises(ValueError, match="needs select_support"):
        register_algorithm("half_aircomp", Algorithm(
            name="half_aircomp", aircomp=True))
    with pytest.raises(ValueError, match="needs a"):
        register_algorithm("no_agg", Algorithm(
            name="no_agg", aircomp=False))
