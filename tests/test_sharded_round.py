"""Sharded cohort execution (DESIGN.md §7): parity with the vmapped path.

The sharded round must match the single-device round — params, metrics,
residuals — to fp32 accumulation order, on a multi-device CPU mesh. The
inline tests run whenever the process already has >= 2 host devices (the
CI docs job forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
on a single-device session a subprocess fallback (marked slow) re-executes
this module under the forced 8-device platform, so the full tier-1 run
exercises the sharded path either way.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs import PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.data import make_federated_classification
from repro.fl import make_round_fn, make_training_fn, setup
from repro.launch.mesh import cohort_shape, make_cohort_mesh, make_mesh
from repro.models import cnn

MULTI = len(jax.devices()) >= 2
needs_devices = pytest.mark.skipif(
    not MULTI, reason="needs >= 2 host devices (see subprocess fallback)")

BASE = dict(num_clients=30, clients_per_round=8, local_steps=2, rounds=2)


# ------------------------------------------------------------ mesh builder

def test_cohort_shape_divisors():
    assert cohort_shape(32, 8) == (2, 4)       # full mesh, pod <= data
    assert cohort_shape(8, 8) == (2, 4)
    assert cohort_shape(5, 8) == (1, 5)        # largest divisor of r
    assert cohort_shape(6, 4) == (1, 3)
    assert cohort_shape(7, 4) == (1, 1)        # nothing divides -> replicated
    assert cohort_shape(1, 8) == (1, 1)
    assert cohort_shape(9, 3) == (1, 3)


def test_cohort_mesh_single_device():
    mesh = make_cohort_mesh(8, devices=jax.devices()[:1])
    assert dict(mesh.shape) == {"pod": 1, "data": 1}


@needs_devices
def test_cohort_mesh_multi_device():
    mesh = make_cohort_mesh(8)
    n = mesh.shape["pod"] * mesh.shape["data"]
    assert n > 1 and 8 % n == 0


# ------------------------------------------------------------ parity

def _make_problem():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    x, y, _, _ = make_federated_classification(
        key, n_clients=30, per_client=30, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, flat.shape[0], unravel, (x, y), loss_fn


@pytest.fixture(scope="module")
def problem():
    return _make_problem()


def _run(problem, cfg, mesh=None, t_rounds=None):
    params, d, unravel, (x, y), loss_fn = problem
    st = setup(jax.random.PRNGKey(1), params, cfg, d)
    if t_rounds is not None:
        fn = make_training_fn(cfg, loss_fn, d, unravel, rounds=t_rounds,
                              mesh=mesh)
    else:
        fn = make_round_fn(cfg, loss_fn, d, unravel, mesh=mesh)
    return fn(params, st.power_limits, x, y, jax.random.PRNGKey(2),
              residuals=st.residuals)


def _assert_parity(problem, extra, mesh, t_rounds=None, atol=5e-5):
    cfg_v = PFELSConfig(**BASE, **extra)
    cfg_s = dataclasses.replace(cfg_v, client_sharding="cohort")
    out_v = _run(problem, cfg_v, t_rounds=t_rounds)
    out_s = _run(problem, cfg_s, mesh=mesh, t_rounds=t_rounds)
    for lv, ls in zip(jax.tree.leaves(out_v), jax.tree.leaves(out_s)):
        np.testing.assert_allclose(np.asarray(lv, np.float32),
                                   np.asarray(ls, np.float32),
                                   atol=atol, rtol=5e-4)


@needs_devices
def test_sharded_round_parity(problem):
    _assert_parity(problem, {}, make_cohort_mesh(BASE["clients_per_round"]))


@needs_devices
def test_sharded_round_parity_fused_kernel(problem):
    _assert_parity(problem, dict(use_fused_kernel=True),
                   make_cohort_mesh(BASE["clients_per_round"]))


@needs_devices
def test_sharded_round_parity_error_feedback(problem):
    # residuals come back as output 3 of round_fn and must match the
    # vmapped scatter-back client-for-client
    _assert_parity(problem, dict(error_feedback=True, transmit_clip=0.5),
                   make_cohort_mesh(BASE["clients_per_round"]))


@needs_devices
def test_sharded_training_fn_parity(problem):
    _assert_parity(problem, dict(error_feedback=True),
                   make_cohort_mesh(BASE["clients_per_round"]), t_rounds=2,
                   atol=1e-4)


@needs_devices
def test_nondivisible_cohort_falls_back_exact(problem):
    """r=5 on a 2- or 3-shard mesh (neither divides 5): the round must
    take the replicated (vmapped) path and match BITWISE."""
    n = min(3, len(jax.devices()))
    bad = make_mesh(np.array(jax.devices()[:n]).reshape(1, n),
                    ("pod", "data"))
    cfg_v = PFELSConfig(**{**BASE, "clients_per_round": 5})
    cfg_s = dataclasses.replace(cfg_v, client_sharding="cohort")
    out_v = _run(problem, cfg_v)
    out_s = _run(problem, cfg_s, mesh=bad)
    for lv, ls in zip(jax.tree.leaves(out_v), jax.tree.leaves(out_s)):
        assert bool(jnp.array_equal(lv, ls))


# ------------------------------------------------- single-device fallback

@pytest.mark.slow
@pytest.mark.skipif(MULTI, reason="inline tests already ran multi-device")
def test_parity_in_subprocess():
    """Re-run this module's parity checks under a forced 8-device host
    platform (XLA device count is fixed at process start, so a fresh
    interpreter is required)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, (proc.stdout, proc.stderr)
    assert "SHARDED PARITY OK" in proc.stdout


if __name__ == "__main__":
    # subprocess entry: run the core parity set with >= 2 devices
    assert len(jax.devices()) >= 2, "forced host device count did not apply"
    prob = _make_problem()
    mesh = make_cohort_mesh(BASE["clients_per_round"])
    _assert_parity(prob, {}, mesh)
    _assert_parity(prob, dict(use_fused_kernel=True), mesh)
    _assert_parity(prob, dict(error_feedback=True, transmit_clip=0.5), mesh)
    _assert_parity(prob, dict(error_feedback=True), mesh, t_rounds=2,
                   atol=1e-4)
    print("SHARDED PARITY OK")
