"""Theorem 5 power control + Lemma 5 power-limit satisfaction, including
the per-channel-model sweep (every registered wireless scenario must
respect the per-device energy cap under perfect and imperfect CSI)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ChannelConfig
from repro.core import channel, channels, power_control, privacy, randk


KW = dict(c1=1.0, eta=0.05, tau=5, epsilon=1.5, r=8, n=100, delta=1e-2,
          sigma0=1.0)


def test_theorem5_is_min_of_caps():
    key = jax.random.PRNGKey(0)
    cfg = ChannelConfig()
    gains = channel.sample_gains(key, 8, cfg)
    p = channel.sample_power_limits(key, 8, 1000, cfg)
    d, k = 1000, 300
    beta = power_control.beta_pfels(gains, p, d=d, k=k, **KW)
    cap_pow = power_control.beta_power_cap(gains, p, d, k, KW["c1"],
                                           KW["eta"], KW["tau"])
    cap_priv = privacy.beta_privacy_cap(KW["epsilon"], KW["eta"], KW["tau"],
                                        KW["c1"], KW["r"], KW["n"],
                                        KW["delta"], KW["sigma0"])
    assert float(beta) == pytest.approx(min(float(cap_pow), cap_priv))


def test_theorem5_beats_grid_search():
    """beta* from (35) is the max feasible beta (P2 objective decreasing)."""
    key = jax.random.PRNGKey(1)
    cfg = ChannelConfig()
    gains = channel.sample_gains(key, 8, cfg)
    p = channel.sample_power_limits(key, 8, 1000, cfg)
    d, k = 1000, 300
    beta_star = float(power_control.beta_pfels(gains, p, d=d, k=k, **KW))
    c2 = privacy.c2_coefficient(KW["eta"], KW["tau"], KW["c1"], KW["r"],
                                KW["n"], KW["delta"], KW["sigma0"])

    def feasible(b):
        ok_priv = c2 * b <= KW["epsilon"] + 1e-12
        per = gains * jnp.sqrt(float(d) * p) / (
            KW["c1"] * KW["eta"] * KW["tau"] * jnp.sqrt(float(k)))
        return ok_priv and b <= float(jnp.min(per)) + 1e-12

    assert feasible(beta_star)
    assert not feasible(beta_star * 1.01)


def test_power_limit_satisfied_statistically():
    """E||x_i||^2 <= P_i when beta uses the Lemma-5 bound."""
    key = jax.random.PRNGKey(2)
    cfg = ChannelConfig()
    r, d, k = 4, 512, 128
    gains = channel.sample_gains(key, r, cfg)
    p = channel.sample_power_limits(key, r, d, cfg)
    beta = power_control.beta_pfels(gains, p, d=d, k=k, **KW)
    # worst-case update norm eta*tau*C1 (Assumption 1)
    u = jax.random.normal(key, (d,))
    u = u / jnp.linalg.norm(u) * KW["eta"] * KW["tau"] * KW["c1"]
    energies = []
    for s in range(300):
        idx = randk.sample_indices(jax.random.PRNGKey(s), d, k)
        for i in range(r):
            x_i = (beta / gains[i]) * randk.project(u, idx)
            energies.append((i, float(jnp.sum(x_i ** 2))))
    for i in range(r):
        mean_e = np.mean([e for j, e in energies if j == i])
        assert mean_e <= float(p[i]) * 1.05


def test_power_limit_respected_under_imperfect_csi():
    """Regression (ISSUE 4): with imperfect CSI each device precompensates
    with its OBSERVED gain h_est, so its transmit energy is
    (beta/h_est_i)^2 ||A u||^2 — the Eq. 34c cap bounds it by P_i only
    when beta is designed from h_est. Designing from the true gains (the
    old behavior) violates P_i whenever h_i < h_i^est. Checks the
    statistical per-device bound E||x_i||^2 <= P_i (Lemma-5 expectation
    over the rand-k support) for the est-designed beta, and that the
    true-gain design really was violating."""
    r, d, k = 6, 512, 128
    cfg = ChannelConfig(csi_error=0.3)
    # huge epsilon so the power cap (not the privacy cap) binds beta
    kw = dict(KW, epsilon=1e9, r=r)
    ete = kw["eta"] * kw["tau"] * kw["c1"]   # Assumption-1 norm bound

    def per_device_expected_energy(beta, comp):
        # E_A ||(beta/comp_i) A u||^2 = (beta/comp_i)^2 (k/d) (eta tau C1)^2
        return (beta / comp) ** 2 * (k / d) * ete ** 2

    old_violations = 0
    for seed in range(25):
        kg, ke, kp = jax.random.split(jax.random.PRNGKey(seed), 3)
        gains = channel.sample_gains(kg, r, cfg)
        est = channel.estimate_gains(ke, gains, cfg)
        p = channel.sample_power_limits(kp, r, d, cfg)
        beta_new = power_control.beta_pfels(est, p, d=d, k=k, **kw)
        e_new = per_device_expected_energy(beta_new, est)
        assert bool(jnp.all(e_new <= p * (1 + 1e-5))), seed
        beta_old = power_control.beta_pfels(gains, p, d=d, k=k, **kw)
        e_old = per_device_expected_energy(beta_old, est)
        old_violations += int(bool(jnp.any(e_old > p * (1 + 1e-5))))
    assert old_violations > 0   # the bug was real


def test_per_device_energy_statistical_under_imperfect_csi():
    """Same bound, realized: average per-device energy over many rand-k
    supports stays <= P_i (tolerance) when beta is designed from the
    observed gains — the end-to-end form of the regression."""
    key = jax.random.PRNGKey(11)
    cfg = ChannelConfig(csi_error=0.3)
    r, d, k = 4, 512, 128
    kg, ke, kp, ku = jax.random.split(key, 4)
    gains = channel.sample_gains(kg, r, cfg)
    est = channel.estimate_gains(ke, gains, cfg)
    p = channel.sample_power_limits(kp, r, d, cfg)
    kw = dict(KW, epsilon=1e9, r=r)
    beta = power_control.beta_pfels(est, p, d=d, k=k, **kw)
    u = jax.random.normal(ku, (d,))
    u = u / jnp.linalg.norm(u) * kw["eta"] * kw["tau"] * kw["c1"]
    energies = {i: [] for i in range(r)}
    for s in range(300):
        idx = randk.sample_indices(jax.random.PRNGKey(s), d, k)
        proj = randk.project(u, idx)
        for i in range(r):
            # the device transmits with its OBSERVED gain
            x_i = (beta / est[i]) * proj
            energies[i].append(float(jnp.sum(x_i ** 2)))
    for i in range(r):
        assert np.mean(energies[i]) <= float(p[i]) * 1.05, i


# ------------------------------------------- channel-model property sweep
# parametrized grids instead of hypothesis (not in the pinned environment,
# same convention as tests/test_privacy.py)

def _model_chan_cfg(model: str, csi: float) -> ChannelConfig:
    return ChannelConfig(model=model, csi_error=csi, num_antennas=8,
                         markov_rho=0.9, dropout_prob=0.3)


@pytest.mark.parametrize("csi", [0.0, 0.3])
@pytest.mark.parametrize("model", sorted(channels.list_channel_models()))
def test_property_energy_cap_holds_for_every_channel_model(model, csi):
    """For EVERY registered scenario, under perfect and imperfect CSI:
    each transmitting device's expected energy
    E_A ||(beta/g_i^obs) A u||^2 = (beta/g_i^obs)^2 (k/d)(eta tau C1)^2
    stays <= P_i when beta is designed through the registry view
    (``design_gains``: observed effective gains, dropped clients lifted).
    A huge epsilon makes the power cap — not the privacy cap — bind."""
    r, d, k = 6, 512, 128
    cfg = _model_chan_cfg(model, csi)
    m = channels.get_channel_model(model)
    kw = dict(c1=1.0, eta=0.05, tau=5, epsilon=1e9, r=r, n=100,
              delta=1e-2, sigma0=channels.effective_noise_std(cfg))
    ete = kw["eta"] * kw["tau"] * kw["c1"]   # Assumption-1 norm bound
    checked = 0
    for seed in range(20):
        kg, kc, kp, ki = jax.random.split(jax.random.PRNGKey(seed), 4)
        carry = m.init(ki, r, cfg)
        _, cr = m.step(carry, cfg, r, jnp.arange(r), kg, kc)
        p = channel.sample_power_limits(kp, r, d, cfg)
        beta = power_control.beta_pfels(
            channels.design_gains(cr), p, d=d, k=k, **kw)
        obs = channels.observed_gains(cr)
        energy = (beta / obs) ** 2 * (k / d) * ete ** 2
        tx = (np.ones(r) if cr.tx_mask is None else np.asarray(cr.tx_mask))
        ok = np.asarray(energy <= p * (1 + 1e-5)) | (tx == 0.0)
        assert bool(np.all(ok)), (model, csi, seed)
        checked += int(tx.sum())
    assert checked > 0


@pytest.mark.parametrize("model", sorted(channels.list_channel_models()))
def test_property_realized_energy_zero_for_dropped(model):
    """What a masked client actually radiates is zero — the aggregate
    transmit-energy metric only charges realized transmitters."""
    r, d, k = 6, 256, 64
    cfg = _model_chan_cfg(model, 0.0)
    m = channels.get_channel_model(model)
    kg, kc, ki, ku = jax.random.split(jax.random.PRNGKey(1), 4)
    _, cr = m.step(m.init(ki, r, cfg), cfg, r, jnp.arange(r), kg, kc)
    u = jax.random.normal(ku, (r, d))
    from repro.core import aggregation
    idx = randk.sample_indices(kg, d, k)
    _, energy_all, _ = aggregation.aircomp_aggregate(
        u, idx, cr.gains, 1.0, ku, d=d,
        sigma0=channels.effective_noise_std(cfg), r=r)
    _, energy_masked, _ = aggregation.aircomp_aggregate(
        u, idx, cr.gains, 1.0, ku, d=d,
        sigma0=channels.effective_noise_std(cfg), r=r,
        tx_mask=cr.tx_mask)
    if cr.tx_mask is None or bool(jnp.all(cr.tx_mask == 1.0)):
        assert float(energy_masked) == float(energy_all)
    else:
        assert float(energy_masked) < float(energy_all)


def test_wfl_pdp_caps_wfl_p():
    key = jax.random.PRNGKey(3)
    cfg = ChannelConfig()
    gains = channel.sample_gains(key, 8, cfg)
    p = channel.sample_power_limits(key, 8, 1000, cfg)
    kw = {k: v for k, v in KW.items() if k in ("c1", "eta", "tau")}
    b_p = power_control.beta_wfl_p(gains, p, **kw)
    b_pdp = power_control.beta_wfl_pdp(gains, p, **KW)
    assert float(b_pdp) <= float(b_p) + 1e-12


def test_transmit_energy_formula():
    gains = jnp.array([0.5, 0.25])
    sq = jnp.array([2.0, 8.0])
    e = power_control.transmit_energy(1.0, gains, sq)
    assert float(e) == pytest.approx(2.0 / 0.25 + 8.0 / 0.0625)
