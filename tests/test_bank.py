"""ClientBank (DESIGN.md §10): resident-vs-streamed bit parity under the
golden key, chunked resume with the bank carried in TrainState, the PRNG
key-lane contract (DESIGN.md §5), the streamed cohort data pipeline, and
TrainState+bank checkpointing."""
import dataclasses
import functools
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro import checkpoint
from repro.configs import ChannelConfig, PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.core import aggregation, channel, power_control, randk
from repro.data import (ArraySource, make_federated_classification,
                        make_population_source, prefetch_cohorts)
from repro.data.loader import ClientFnSource
from repro.fl import Trainer, make_bank, rounds
from repro.fl.api import replace
from repro.fl.bank import cohort_lane_keys
from repro.fl.client import local_train, model_update

BASE = dict(num_clients=20, clients_per_round=4, local_steps=2,
            local_lr=0.05, compression_ratio=0.3, epsilon=2.0, rounds=2)

PARITY_CASES = {
    "base": {},
    "error_feedback": dict(error_feedback=True, transmit_clip=0.5),
    "server_topk": dict(randk_mode="server_topk"),
    "fused_kernel": dict(use_fused_kernel=True),
    "imperfect_csi": dict(channel=ChannelConfig(csi_error=0.2)),
}


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    from repro.models import cnn
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    x, y, xt, yt = make_federated_classification(
        key, n_clients=20, per_client=20, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, flat.shape[0], unravel, (x, y, xt, yt), loss_fn


def _flat(p):
    return ravel_pytree(p)[0]


def _trainer(cfg, problem):
    params, _, _, _, loss_fn = problem
    trainer = Trainer(cfg, loss_fn, params)
    state = replace(trainer.init(jax.random.PRNGKey(1)),
                    key=jax.random.PRNGKey(2))
    return trainer, state


def _both_backends(case_cfg, problem):
    cfg_r = PFELSConfig(**BASE, **case_cfg)
    cfg_s = dataclasses.replace(cfg_r, bank_backend="streamed")
    return _trainer(cfg_r, problem), _trainer(cfg_s, problem)


def _assert_states_equal(sr, ss):
    """Bitwise equality of every TrainState leaf across backends."""
    assert bool(jnp.array_equal(_flat(sr.params), _flat(ss.params)))
    assert bool(jnp.array_equal(sr.prev_delta, jnp.asarray(ss.prev_delta)))
    if sr.bank.residuals is None:
        assert ss.bank.residuals is None
    else:
        assert bool(jnp.array_equal(sr.bank.residuals,
                                    jnp.asarray(ss.bank.residuals)))
    assert np.array_equal(np.asarray(sr.bank.counts),
                          np.asarray(ss.bank.counts))
    assert np.array_equal(np.asarray(sr.bank.lanes),
                          np.asarray(ss.bank.lanes))
    assert bool(jnp.array_equal(sr.ledger.eps_sum, ss.ledger.eps_sum))
    assert bool(jnp.array_equal(sr.ledger.eps_max, ss.ledger.eps_max))
    assert int(sr.ledger.spends) == int(ss.ledger.spends)
    assert int(sr.round) == int(ss.round)
    assert bool(jnp.array_equal(sr.key, ss.key))


# --------------------------------------------------- backend bit parity

@pytest.mark.parametrize("case", sorted(PARITY_CASES))
def test_resident_streamed_bit_parity(problem, case):
    """The streamed bank (host-side state + prefetched cohort slices) is
    bit-identical to the resident scan at small n under the same key —
    params, EF residuals, server_topk prev_delta, ledger totals, lanes,
    counts, and every stacked metric."""
    (tr, sr), (ts, ss) = _both_backends(PARITY_CASES[case], problem)
    x, y = problem[3][0], problem[3][1]
    sr, mr = tr.run(sr, x, y, rounds=3)
    ss, ms = ts.run(ss, np.asarray(x), np.asarray(y), rounds=3)
    _assert_states_equal(sr, ss)
    assert set(mr) == set(ms)
    for k in mr:
        assert bool(jnp.array_equal(mr[k], jnp.asarray(ms[k]))), k


def test_streamed_step_matches_resident_step(problem):
    """step consumes state.key whole under both backends (the resident /
    legacy schedule, not split(key, 1))."""
    (tr, sr), (ts, ss) = _both_backends(
        dict(error_feedback=True), problem)
    x, y = problem[3][0], problem[3][1]
    sr1, mr = tr.step(sr, x, y)
    ss1, ms = ts.step(ss, np.asarray(x), np.asarray(y))
    _assert_states_equal(sr1, ss1)
    for k in mr:
        assert bool(jnp.array_equal(mr[k], jnp.asarray(ms[k]))), k


def test_chunked_resume_carries_bank(problem):
    """run(T1) then run(T2) with the bank carried in TrainState: both
    backends stay bit-identical through the chunk boundary, participation
    counts accumulate, and the resumed PRNG stream advances."""
    (tr, sr), (ts, ss) = _both_backends(
        dict(error_feedback=True, randk_mode="server_topk"), problem)
    x, y = problem[3][0], problem[3][1]
    xs, ys = np.asarray(x), np.asarray(y)
    sr1, _ = tr.run(sr, x, y, rounds=2)
    sr2, _ = tr.run(sr1, x, y, rounds=3)
    ss1, _ = ts.run(ss, xs, ys, rounds=2)
    ss2, _ = ts.run(ss1, xs, ys, rounds=3)
    _assert_states_equal(sr2, ss2)
    assert int(sr2.round) == 5
    assert int(np.asarray(sr2.bank.counts).sum()) \
        == 5 * BASE["clients_per_round"]
    # the streamed run must not mutate the caller's states in place
    assert int(np.asarray(ss.bank.counts).sum()) == 0
    assert int(np.asarray(ss1.bank.counts).sum()) \
        == 2 * BASE["clients_per_round"]


# --------------------------------------------------- key-lane contract

def test_key_lane_contract(problem):
    """Pins which of the 7 round-key lanes feeds which draw (DESIGN.md
    §5): the whole round is recomputed from the documented lanes with the
    same public primitives and must reproduce the Trainer's outputs —
    selection (0), client train keys (1), gains (2), support (3), channel
    noise (4), bank lanes (5), CSI estimation (6). A silent lane shift
    changes every recomputed quantity."""
    params, d, unravel, (x, y, _, _), loss_fn = problem
    chan = ChannelConfig(csi_error=0.3)
    cfg = PFELSConfig(**BASE, channel=chan)
    trainer, state = _trainer(cfg, problem)
    new_state, metrics = trainer.step(state, x, y)

    n, r = cfg.num_clients, cfg.clients_per_round
    k = max(int(round(cfg.compression_ratio * d)), 1)
    ks = rounds.split_round_key(state.key)

    # lane 0: selection; observable through the participation counts
    sel = rounds.sample_cohort(ks[0], n, r)
    counts = np.asarray(new_state.bank.counts)
    assert counts.sum() == r
    assert np.array_equal(np.sort(np.asarray(sel)),
                          np.flatnonzero(counts == 1))

    # lane 5: per-client bank lanes fold the client id into ks[5]
    lanes = np.asarray(new_state.bank.lanes)
    expect_lanes = np.asarray(cohort_lane_keys(ks[5], sel))
    assert np.array_equal(lanes[np.asarray(sel)], expect_lanes)

    # lanes 1-4 and 6: recompute the full round from the pinned lanes
    train = functools.partial(
        local_train, loss_fn=loss_fn, steps=cfg.local_steps,
        lr=cfg.local_lr, clip=cfg.clip, momentum=cfg.momentum)
    cx, cy = x[sel], y[sel]
    ck = jax.random.split(ks[1], r)                       # lane 1
    new_p, losses = jax.vmap(
        lambda cx_, cy_, k_: train(params, cx_, cy_, k_))(cx, cy, ck)
    updates = jax.vmap(lambda p_: model_update(params, p_))(new_p)
    flat_updates = jax.vmap(lambda u: ravel_pytree(u)[0])(updates)

    gains = channel.sample_gains(ks[2], r, chan)          # lane 2
    gains_est = channel.estimate_gains(ks[6], gains, chan)  # lane 6
    idx = randk.sample_indices(ks[3], d, k)               # lane 3
    p_sel = state.power_limits[sel]
    beta = power_control.beta_pfels(
        gains_est, p_sel, d=d, k=k, c1=cfg.clip, eta=cfg.local_lr,
        tau=cfg.local_steps, epsilon=cfg.epsilon, r=r, n=n,
        delta=cfg.resolved_delta(), sigma0=chan.noise_std)
    delta_hat, energy, _ = aggregation.aircomp_aggregate(
        flat_updates, idx, gains, beta, ks[4], d=d,       # lane 4
        sigma0=chan.noise_std, r=r, gains_est=gains_est)

    np.testing.assert_allclose(float(metrics["train_loss"]),
                               float(jnp.mean(losses)), rtol=1e-6)
    np.testing.assert_allclose(float(metrics["beta"]), float(beta),
                               rtol=1e-6)
    np.testing.assert_allclose(float(metrics["energy"]), float(energy),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_state.prev_delta),
                               np.asarray(delta_hat), rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(_flat(new_state.params)),
        np.asarray(_flat(params) + delta_hat), rtol=1e-5, atol=1e-7)


# ------------------------------------------------- streamed data pipeline

def test_cohort_source_matches_array_gather(problem):
    """ArraySource.cohort(sel) is exactly the resident data_x[sel]."""
    x, y = problem[3][0], problem[3][1]
    src = ArraySource(x, y)
    sel = np.array([3, 0, 17, 5])
    cx, cy = src.cohort(sel)
    assert np.array_equal(cx, np.asarray(x)[sel])
    assert np.array_equal(cy, np.asarray(y)[sel])


def test_streamed_run_accepts_source_and_arrays(problem):
    """Passing (x, y) arrays and passing an ArraySource are the same
    streamed run."""
    (_, _), (ts, ss) = _both_backends({}, problem)
    x, y = problem[3][0], problem[3][1]
    s_a, m_a = ts.run(ss, np.asarray(x), np.asarray(y), rounds=2)
    s_b, m_b = ts.run(ss, ArraySource(x, y), rounds=2)
    _assert_states_equal(s_a, s_b)
    for k in m_a:
        assert np.array_equal(m_a[k], m_b[k]), k


def test_population_source_deterministic_o_r():
    """make_population_source: same client -> same samples whenever it is
    sampled; only (r, ...) batches are materialized."""
    src, xt, yt = make_population_source(
        jax.random.PRNGKey(3), n_clients=50_000, per_client=6,
        num_classes=10, image_shape=(1, 8, 8))
    assert src.n == 50_000
    a = src.cohort(np.array([7, 49_999, 123]))
    b = src.cohort(np.array([123, 7]))
    assert a[0].shape == (3, 6, 1, 8, 8) and a[1].shape == (3, 6)
    np.testing.assert_array_equal(np.asarray(a[0][0]), np.asarray(b[0][1]))
    np.testing.assert_array_equal(np.asarray(a[0][2]), np.asarray(b[0][0]))
    np.testing.assert_array_equal(np.asarray(a[1][0]), np.asarray(b[1][1]))
    assert not np.array_equal(np.asarray(a[0][0]), np.asarray(a[0][1]))
    assert xt.shape[0] == yt.shape[0] >= 200


def test_prefetch_orders_and_propagates_errors():
    x = np.arange(40, dtype=np.float32).reshape(10, 4)
    y = np.arange(10, dtype=np.int32)
    src = ArraySource(x, y)
    sels = [np.array([1, 2]), np.array([9, 0]), np.array([5, 5])]
    got = list(prefetch_cohorts(src, sels))
    assert len(got) == 3
    for sel, (cx, cy) in zip(sels, got):
        assert np.array_equal(np.asarray(cx), x[sel])
        assert np.array_equal(np.asarray(cy), y[sel])

    def boom(sel):
        raise RuntimeError("generator failed")

    bad = ClientFnSource(boom, 10)
    with pytest.raises(RuntimeError, match="generator failed"):
        list(prefetch_cohorts(bad, sels))

    # abandoning the generator mid-stream must release the worker thread
    # (it would otherwise block forever on the bounded queue)
    import threading
    gen = prefetch_cohorts(src, [np.array([0, 1])] * 50, depth=1)
    next(gen)
    gen.close()
    deadline = 50
    while deadline and any(t.name == "cohort-prefetch" and t.is_alive()
                           for t in threading.enumerate()):
        import time
        time.sleep(0.1)
        deadline -= 1
    assert not any(t.name == "cohort-prefetch" and t.is_alive()
                   for t in threading.enumerate())


def test_streamed_rejects_mismatched_source_and_zero_rounds(problem):
    (_, _), (ts, ss) = _both_backends({}, problem)
    src, _, _ = make_population_source(
        jax.random.PRNGKey(0), n_clients=99, per_client=4,
        num_classes=10, image_shape=(1, 8, 8))
    with pytest.raises(ValueError, match="cfg.num_clients"):
        ts.run(ss, src, rounds=2)
    x, y = problem[3][0], problem[3][1]
    with pytest.raises(ValueError, match="rounds >= 1"):
        ts.run(ss, np.asarray(x), np.asarray(y), rounds=0)


def test_streamed_trains_on_population_source(problem):
    """End-to-end: streamed bank + on-demand population source at an n
    where a resident (n, samples, ...) tensor would be pointless."""
    params, d, _, _, loss_fn = problem
    cfg = PFELSConfig(**{**BASE, "num_clients": 5_000},
                      error_feedback=True, bank_backend="streamed")
    src, xt, yt = make_population_source(
        jax.random.PRNGKey(5), n_clients=5_000, per_client=8,
        num_classes=10, image_shape=(1, 8, 8))
    trainer = Trainer(cfg, loss_fn, params)
    state = trainer.init(jax.random.PRNGKey(1))
    state, m = trainer.run(state, src, rounds=2)
    assert np.isfinite(np.asarray(m["train_loss"])).all()
    assert state.bank.residuals.shape == (5_000, d)
    assert isinstance(state.bank.residuals, np.ndarray)  # host-side
    assert int(np.asarray(state.bank.counts).sum()) \
        == 2 * cfg.clients_per_round


# ------------------------------------------------------- checkpointing

@pytest.mark.parametrize("backend", ["resident", "streamed"])
def test_checkpoint_roundtrip_with_bank(problem, backend):
    """save_train_state/restore_train_state carry the bank; resuming from
    the checkpoint equals resuming from the live state, bitwise."""
    cfg = PFELSConfig(**BASE, error_feedback=True, bank_backend=backend)
    trainer, state = _trainer(cfg, problem)
    x, y = problem[3][0], problem[3][1]
    s1, _ = trainer.run(state, x, y, rounds=2)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck")
        checkpoint.save_train_state(path, s1, backend=backend)
        meta = checkpoint.load_meta(path)
        assert meta["bank_backend"] == backend
        assert meta["round"] == 2
        restored = checkpoint.restore_train_state(
            path, trainer.init(jax.random.PRNGKey(1)))
    if backend == "streamed":
        assert isinstance(restored.bank.residuals, np.ndarray)
    a, _ = trainer.run(s1, x, y, rounds=2)
    b, _ = trainer.run(restored, x, y, rounds=2)
    _assert_states_equal(a, b)


# ------------------------------------------------------------ validation

def test_bank_validation(problem):
    with pytest.raises(ValueError, match="unknown bank backend"):
        make_bank("ram", 10, 4, False)
    cfg = PFELSConfig(**BASE, bank_backend="streamed",
                      client_sharding="cohort")
    with pytest.raises(ValueError, match="streamed"):
        Trainer(cfg, problem[4], problem[0])
