"""Launch-layer glue: input specs + lower/compile for every step kind on a
host mesh with reduced archs (the 512-device production meshes are covered
by the dry-run itself)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.configs.base import PFELSConfig
from repro.configs.shapes import InputShape
from repro.launch import inputs as I
from repro.launch import steps as S
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models import transformer as T
from repro.sharding.rules import tree_shardings

TRAIN_S = InputShape("t_train", 128, 8, "train")
PREFILL_S = InputShape("t_prefill", 256, 4, "prefill")
DECODE_S = InputShape("t_decode", 256, 4, "decode")
LONG_S = InputShape("long_500k", 512, 1, "decode")  # triggers window mode


def _params_in(cfg, mesh):
    with use_mesh(mesh):
        shapes = T.init_shapes(cfg)
        logical = T.logical_axes(cfg)
    sh = tree_shardings(mesh, logical, shapes)
    return jax.tree.map(
        lambda sd, s: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=s),
        shapes, sh)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "zamba2-2.7b",
                                  "granite-moe-3b-a800m", "whisper-tiny",
                                  "qwen2-vl-72b"])
@pytest.mark.parametrize("shape", [TRAIN_S, PREFILL_S, DECODE_S, LONG_S])
def test_lower_compile_all_kinds(arch, shape):
    cfg = reduced_config(arch)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    pfels = PFELSConfig(num_clients=100, compression_ratio=0.5, epsilon=2.0,
                        local_steps=1)
    params_in = _params_in(cfg, mesh)
    with use_mesh(mesh):
        if shape.kind == "train":
            batch = I.train_batch_specs(cfg, shape, mesh)
            d = sum(x.size for x in jax.tree.leaves(params_in))
            step = S.make_pfels_train_step(cfg, pfels, d, mesh)
            lowered = jax.jit(step).lower(
                params_in, batch, jax.ShapeDtypeStruct((2,), jnp.uint32))
        elif shape.kind == "prefill":
            batch = I.prefill_batch_specs(cfg, shape, mesh)
            step = S.make_prefill_step(cfg)
            lowered = jax.jit(step).lower(params_in, batch)
        else:
            window = I.long_context_window(cfg, shape)
            spec = I.decode_specs(cfg, shape, mesh, window=window)
            step = S.make_serve_step(cfg, window=window)
            kw = {}
            if cfg.is_encoder_decoder:
                kw["enc_out"] = spec["enc_out"]
            lowered = jax.jit(step).lower(params_in, spec["token"],
                                          spec["caches"], **kw)
        compiled = lowered.compile()
    assert compiled.memory_analysis() is not None
    coll = collective_bytes(compiled.as_text())
    terms = roofline_terms(compiled.cost_analysis(), coll, mesh.size)
    assert terms["dominant"] in ("compute", "memory", "collective")


def test_long_context_window_policy():
    assert I.long_context_window(reduced_config("mamba2-130m"),
                                 LONG_S) is None            # attention-free
    assert I.long_context_window(reduced_config("phi3-mini-3.8b"),
                                 LONG_S) == 256             # sliding window
    assert I.long_context_window(reduced_config("phi3-mini-3.8b"),
                                 DECODE_S) is None          # full attention
