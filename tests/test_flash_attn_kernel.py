"""flash_attn Pallas kernel vs plain-softmax oracle: GQA / causal /
windowed / shape sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import attention
from repro.kernels.flash_attn.ref import attention_ref


def _mk(key, b, sq, skv, h, hkv, dh, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, dh)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,sq,h,hkv,dh", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA g=4
    (1, 512, 4, 1, 128),    # MQA
])
def test_flash_matches_ref_causal(b, sq, h, hkv, dh):
    q, k, v = _mk(jax.random.PRNGKey(b + sq), b, sq, sq, h, hkv, dh)
    out = attention(q, k, v, causal=True, block_q=128, block_kv=128)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_flash_windowed():
    q, k, v = _mk(jax.random.PRNGKey(0), 1, 256, 256, 4, 4, 64)
    out = attention(q, k, v, causal=True, window=64, block_q=64,
                    block_kv=64)
    ref = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_flash_noncausal():
    q, k, v = _mk(jax.random.PRNGKey(1), 2, 128, 128, 2, 2, 64)
    out = attention(q, k, v, causal=False)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_flash_bf16():
    q, k, v = _mk(jax.random.PRNGKey(2), 1, 128, 128, 4, 2, 64, jnp.bfloat16)
    out = attention(q, k, v, causal=True, block_q=64, block_kv=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=0.03)


def test_flash_odd_blocks_fall_back():
    """Non-divisible block sizes degrade to one block (still correct)."""
    q, k, v = _mk(jax.random.PRNGKey(3), 1, 96, 96, 2, 2, 64)
    out = attention(q, k, v, causal=True, block_q=64, block_kv=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5)
