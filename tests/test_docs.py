"""Docs stay truthful: every file referenced from DESIGN.md /
docs/paper_map.md / README.md exists, and every `DESIGN.md §N` citation in
the sources resolves to a real section (tools/check_doc_links.py)."""
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tools"))

from check_doc_links import check_design_sections, check_doc_paths


def test_doc_file_references_resolve():
    assert check_doc_paths() == []


def test_design_section_citations_resolve():
    assert check_design_sections() == []


def test_design_and_paper_map_exist():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    assert os.path.exists(os.path.join(root, "DESIGN.md"))
    assert os.path.exists(os.path.join(root, "docs", "paper_map.md"))
