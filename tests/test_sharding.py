"""Sharding rules: divisibility fallback, cache specs, exclusions."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.models.attention import kv_cache_spec
from repro.sharding.rules import exclude_axes, resolve_spec


@pytest.fixture
def mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return make_mesh(dev, ("data", "model"))


def test_resolve_divisible(mesh):
    assert resolve_spec(("fsdp", "tensor"), (4, 8), mesh) == P("data",
                                                               "model")


def test_resolve_drops_missing_axis(mesh):
    # 'pod' missing from this mesh -> batch = data only
    assert resolve_spec(("batch", None), (4, 4), mesh) == P("data", None)


def test_exclude_axes(mesh):
    with exclude_axes("data"):
        assert resolve_spec(("fsdp", "tensor"), (4, 8), mesh) == \
            P(None, "model")
    assert resolve_spec(("fsdp", "tensor"), (4, 8), mesh) == P("data",
                                                               "model")


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_kv_cache_spec_batch_shardable():
    """Batch over data, head_dim over model (local decode token write)."""
    m = FakeMesh({"data": 16, "model": 16})
    spec = kv_cache_spec((128, 32768, 8, 128), m)
    assert spec == (("data",), None, None, "model")


def test_kv_cache_spec_batch1_long():
    """batch=1: replicate batch; still shard head_dim over model."""
    m = FakeMesh({"data": 16, "model": 16})
    spec = kv_cache_spec((1, 524288, 8, 128), m)
    assert spec[0] is None
    assert spec[3] == "model"


def test_kv_cache_spec_heads_fallback():
    """Dh not divisible -> fall back to kv heads over model."""
    m = FakeMesh({"data": 16, "model": 16})
    spec = kv_cache_spec((128, 32768, 32, 100), m)
    assert spec == (("data",), None, "model", None)


def test_kv_cache_spec_multipod():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = kv_cache_spec((128, 32768, 8, 128), m)
    assert spec[0] == ("pod", "data")


def test_nondivisible_replicates(mesh):
    # dim 5 not divisible by nothing on a 1-dev mesh, still fine
    s = resolve_spec(("tensor",), (5,), mesh)
    assert s == P(None) or s == P("model")  # model axis size 1 divides
