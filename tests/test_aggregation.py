"""AirComp aggregation: Alg. 2 exactness, Lemma 1 unbiasedness, baselines,
simulation/production equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, randk


def test_aircomp_matches_manual():
    key = jax.random.PRNGKey(0)
    r, d, k = 3, 40, 10
    updates = jax.random.normal(key, (r, d))
    gains = jnp.array([0.1, 0.05, 0.02])
    idx = randk.sample_indices(key, d, k)
    beta = 0.7
    sigma0 = 0.3
    delta_hat, energy, y = aggregation.aircomp_aggregate(
        updates, idx, gains, beta, key, d=d, sigma0=sigma0, r=r)
    # manual: y = sum_i |h_i| (beta/|h_i|) A u_i + z = beta sum A u_i + z
    noise = sigma0 * jax.random.normal(key, (k,))
    y_manual = beta * jnp.sum(updates[:, idx], axis=0) + noise
    np.testing.assert_allclose(y, y_manual, rtol=1e-5)
    dh_manual = jnp.zeros((d,)).at[idx].set(y_manual) / (r * beta)
    np.testing.assert_allclose(delta_hat, dh_manual, rtol=1e-5)
    e_manual = jnp.sum((beta / gains[:, None] * updates[:, idx]) ** 2)
    np.testing.assert_allclose(energy, e_manual, rtol=1e-5)


def test_lemma1_unbiased_aggregate():
    """E[Delta_hat] = (k/d) * mean_i Delta_i over omega and noise."""
    key = jax.random.PRNGKey(1)
    r, d, k = 4, 32, 8
    updates = jax.random.normal(key, (r, d))
    gains = jnp.full((r,), 0.05)
    beta, sigma0 = 1.3, 0.5

    def one(seed):
        kk = jax.random.PRNGKey(seed)
        idx = randk.sample_indices(kk, d, k)
        dh, _, _ = aggregation.aircomp_aggregate(
            updates, idx, gains, beta, jax.random.fold_in(kk, 1), d=d,
            sigma0=sigma0, r=r)
        return dh

    mean = jnp.mean(jax.vmap(one)(jnp.arange(4000)), axis=0)
    expect = (k / d) * jnp.mean(updates, axis=0)
    np.testing.assert_allclose(mean, expect, atol=0.03)


def test_unbiased_rescale_flag():
    key = jax.random.PRNGKey(2)
    r, d, k = 2, 16, 4
    updates = jax.random.normal(key, (r, d))
    gains = jnp.full((r,), 0.05)
    idx = randk.sample_indices(key, d, k)
    dh, _, _ = aggregation.aircomp_aggregate(
        updates, idx, gains, 1.0, key, d=d, sigma0=0.0, r=r)
    dh2, _, _ = aggregation.aircomp_aggregate(
        updates, idx, gains, 1.0, key, d=d, sigma0=0.0, r=r,
        unbiased_rescale=True)
    np.testing.assert_allclose(dh2, dh * d / k, rtol=1e-6)


def test_dp_fedavg_clips():
    key = jax.random.PRNGKey(3)
    updates = 100.0 * jax.random.normal(key, (5, 20))
    out = aggregation.dp_fedavg_aggregate(updates, clip=1.0, sigma=0.0,
                                          noise_key=key, r=5)
    assert float(jnp.linalg.norm(out)) <= 1.0 + 1e-5


def test_fedavg_mean():
    u = jnp.arange(12.0).reshape(3, 4)
    np.testing.assert_allclose(aggregation.fedavg_aggregate(u),
                               u.mean(0), rtol=1e-6)


def test_production_aggregate_single_client_noise_free():
    """Production (mask-mode) path: with sigma0=0 and r=1 the output is
    beta-invariant and equals mask * update."""
    key = jax.random.PRNGKey(4)
    tree = {"w": jax.random.normal(key, (8, 8)),
            "b": jax.random.normal(key, (8,))}
    masks = randk.mask_tree(key, tree, 0.5)
    out = aggregation.pfels_production_aggregate(
        tree, masks, beta=3.0, r=1, sigma0=0.0, noise_key=key,
        axis_name=None)
    expect = randk.apply_mask_tree(tree, masks)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_allclose(a, b, rtol=1e-5)


def test_production_noise_only_on_masked_coords():
    key_mask, key_noise = jax.random.split(jax.random.PRNGKey(5))
    tree = {"w": jnp.zeros((64, 64))}
    masks = randk.mask_tree(key_mask, tree, 0.25)
    out = aggregation.pfels_production_aggregate(
        tree, masks, beta=1.0, r=1, sigma0=1.0, noise_key=key_noise,
        axis_name=None)
    m = masks["w"]
    # unmasked coordinates receive no noise
    assert float(jnp.max(jnp.abs(out["w"] * (1 - m)))) == 0.0
    assert float(jnp.std(out["w"][m.astype(bool)])) > 0.5
