"""Beyond-paper extensions: imperfect CSI + server-guided top-k."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.configs import ChannelConfig, PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.core import aggregation, channel
from repro.data import make_federated_classification
from repro.fl import make_round_fn, setup
from repro.models import cnn


def test_estimate_gains_unbiased_and_bounded():
    cfg = ChannelConfig(csi_error=0.1)
    g = channel.sample_gains(jax.random.PRNGKey(0), 5000, cfg)
    ge = channel.estimate_gains(jax.random.PRNGKey(1), g, cfg)
    ratio = ge / g
    assert abs(float(ratio.mean()) - 1.0) < 0.01
    assert abs(float(ratio.std()) - 0.1) < 0.01
    # csi_error=0 is the identity
    cfg0 = ChannelConfig(csi_error=0.0)
    np.testing.assert_array_equal(
        channel.estimate_gains(jax.random.PRNGKey(1), g, cfg0), g)


def test_imperfect_csi_misaligns_aggregate():
    """With estimation error the received aggregate deviates from the
    perfectly aligned one, in proportion to csi_error."""
    key = jax.random.PRNGKey(2)
    r, d, k = 4, 64, 64
    updates = jax.random.normal(key, (r, d))
    gains = channel.sample_gains(key, r, ChannelConfig())
    idx = jnp.arange(d)
    perfect, _, _ = aggregation.aircomp_aggregate(
        updates, idx, gains, 1.0, key, d=d, sigma0=0.0, r=r)
    errs = []
    for ce in (0.05, 0.2):
        cfg = ChannelConfig(csi_error=ce)
        ge = channel.estimate_gains(jax.random.PRNGKey(3), gains, cfg)
        noisy, _, _ = aggregation.aircomp_aggregate(
            updates, idx, gains, 1.0, key, d=d, sigma0=0.0, r=r,
            gains_est=ge)
        errs.append(float(jnp.linalg.norm(noisy - perfect)))
    assert 0 < errs[0] < errs[1]


def test_server_topk_round_runs_and_selects_topk():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    d = flat.shape[0]
    x, y, xt, yt = make_federated_classification(
        key, n_clients=20, per_client=20, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    cfg = PFELSConfig(num_clients=20, clients_per_round=4, local_steps=2,
                      compression_ratio=0.2, epsilon=2.0, rounds=2,
                      randk_mode="server_topk")
    state = setup(jax.random.PRNGKey(1), params, cfg, d)
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    prev = jnp.zeros((d,))
    p = params
    for t in range(2):
        p, m = fn(p, state.power_limits, x, y, jax.random.PRNGKey(t),
                  None, prev)
        assert "delta_hat" in m
        prev = m["delta_hat"]
    # the aggregated update is k-sparse on the selected coords
    k = int(round(0.2 * d))
    assert int(jnp.sum(prev != 0)) <= k
