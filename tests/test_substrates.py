"""Optim / data / checkpoint substrates."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.data import (make_federated_classification, make_lm_sequences,
                        sample_batch)
from repro.optim import (adam_init, adam_update, constant, cosine, sgd_init,
                         sgd_update, warmup_cosine)


def test_sgd_momentum_descends():
    w = jnp.array([10.0])
    v = sgd_init(w)
    loss = lambda w: jnp.sum(w ** 2)
    for _ in range(200):
        g = jax.grad(loss)(w)
        w, v = sgd_update(w, g, v, lr=0.05, momentum=0.9)
    assert float(loss(w)) < 1e-2


def test_adam_descends():
    w = jnp.array([5.0, -3.0])
    st = adam_init(w)
    loss = lambda w: jnp.sum((w - 1.0) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(w)
        w, st = adam_update(w, g, st, lr=0.05)
    assert float(loss(w)) < 1e-2


def test_schedules():
    assert float(constant(0.1)(100)) == pytest.approx(0.1)
    c = cosine(1.0, 100)
    assert float(c(0)) == pytest.approx(1.0)
    assert float(c(100)) == pytest.approx(0.1, abs=1e-3)
    w = warmup_cosine(1.0, 10, 100)
    assert float(w(5)) == pytest.approx(0.5)


def test_federated_data_shapes_and_learnability():
    x, y, xt, yt = make_federated_classification(
        jax.random.PRNGKey(0), n_clients=10, per_client=20,
        num_classes=5, image_shape=(1, 4, 4))
    assert x.shape == (10, 20, 1, 4, 4)
    assert int(y.max()) < 5


def test_dirichlet_skew_more_concentrated():
    _, y_iid, _, _ = make_federated_classification(
        jax.random.PRNGKey(1), n_clients=20, per_client=100,
        num_classes=10, image_shape=(1, 4, 4))
    _, y_skew, _, _ = make_federated_classification(
        jax.random.PRNGKey(1), n_clients=20, per_client=100,
        num_classes=10, image_shape=(1, 4, 4), alpha=0.1)

    def mean_entropy(y):
        ents = []
        for i in range(y.shape[0]):
            p = np.bincount(np.asarray(y[i]), minlength=10) / y.shape[1]
            ents.append(-(p[p > 0] * np.log(p[p > 0])).sum())
        return np.mean(ents)

    assert mean_entropy(y_skew) < mean_entropy(y_iid) - 0.3


def test_lm_sequences():
    s = make_lm_sequences(jax.random.PRNGKey(2), n_seqs=4, seq_len=32,
                          vocab=50)
    assert s.shape == (4, 32) and int(s.max()) < 50


def test_sample_batch():
    x = jnp.arange(100).reshape(20, 5).astype(jnp.float32)
    y = jnp.arange(20)
    b = sample_batch(jax.random.PRNGKey(0), x, y, 8)
    assert b["x"].shape == (8, 5)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        checkpoint.save(path, tree, meta={"round": 7})
        back = checkpoint.restore(path, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))
        assert checkpoint.load_meta(path)["round"] == 7
