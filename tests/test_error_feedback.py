"""Error-feedback option [28-30]: residual memory accumulates the
untransmitted mass and improves sparsified convergence."""
import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs import PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.data import make_federated_classification
from repro.fl import make_round_fn, setup
from repro.models import cnn


def _problem():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    x, y, xt, yt = make_federated_classification(
        key, n_clients=20, per_client=30, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, flat.shape[0], unravel, (x, y, xt, yt), loss_fn


def test_error_feedback_runs_and_accumulates():
    params, d, unravel, (x, y, xt, yt), loss_fn = _problem()
    cfg = PFELSConfig(num_clients=20, clients_per_round=4, local_steps=3,
                      local_lr=0.05, compression_ratio=0.2, epsilon=4.0,
                      rounds=3, error_feedback=True)
    state = setup(jax.random.PRNGKey(1), params, cfg, d)
    assert state.residuals.shape == (20, d)
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    p, res = params, state.residuals
    for t in range(3):
        p, m, res = fn(p, state.power_limits, x, y,
                       jax.random.PRNGKey(10 + t), res)
    # residual mass exists for the clients that participated
    assert float(jnp.sum(jnp.abs(res))) > 0
    assert jnp.isfinite(m["train_loss"])


def test_error_feedback_residual_is_untransmitted_mass():
    """For a participating client: residual = update - sparsified(update),
    i.e. exactly the coordinates outside omega."""
    params, d, unravel, (x, y, xt, yt), loss_fn = _problem()
    cfg = PFELSConfig(num_clients=20, clients_per_round=20, local_steps=2,
                      local_lr=0.05, compression_ratio=0.25, epsilon=4.0,
                      rounds=1, error_feedback=True)
    state = setup(jax.random.PRNGKey(1), params, cfg, d)
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    p, m, res = fn(params, state.power_limits, x, y,
                   jax.random.PRNGKey(0), state.residuals)
    k = int(round(0.25 * d))
    # every client participated; each residual has exactly d-k nonzeros
    # (up to exact zero update coords)
    nz = jnp.sum(res != 0, axis=1)
    assert int(nz.max()) <= d - k
