"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aircomp_combine.ops import combine
from repro.kernels.aircomp_combine.ref import aircomp_combine_ref
from repro.kernels.clip_norm.ops import clip_flat
from repro.kernels.clip_norm.ref import clip_norm_ref
from repro.kernels.randk_gather.ops import gather_rows
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@pytest.mark.parametrize("rows,k_rows", [(64, 16), (256, 256), (512, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_randk_gather_sweep(rows, k_rows, dtype):
    key = jax.random.PRNGKey(rows + k_rows)
    d = rows * 128
    delta = jax.random.normal(key, (d,)).astype(dtype)
    idx = jax.random.permutation(key, rows)[:k_rows]
    out = gather_rows(delta, idx, 1.7)
    ref = (delta.reshape(rows, 128)[idx]
           * jnp.asarray(1.7, dtype)).reshape(-1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("rows,k_rows,r,beta", [(32, 8, 2, 0.5),
                                                (128, 128, 8, 3.0)])
def test_aircomp_combine_sweep(rows, k_rows, r, beta):
    key = jax.random.PRNGKey(rows)
    d = rows * 128
    theta = jax.random.normal(key, (d,))
    y = jax.random.normal(jax.random.fold_in(key, 1), (k_rows * 128,))
    idx = jax.random.permutation(key, rows)[:k_rows]
    out = combine(theta, y, idx, r=r, beta=beta)
    ref = aircomp_combine_ref(theta.reshape(rows, 128),
                              y.reshape(k_rows, 128), idx,
                              1.0 / (r * beta)).reshape(-1)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("n", [100, 128 * 7, 5000])
@pytest.mark.parametrize("clip", [0.5, 10.0, 1e6])
def test_clip_norm_sweep(n, clip):
    key = jax.random.PRNGKey(n)
    x = 3.0 * jax.random.normal(key, (n,))
    out, nrm = clip_flat(x, clip)
    ref, nrm_ref = clip_norm_ref(x, clip)
    np.testing.assert_allclose(float(nrm), float(jnp.linalg.norm(x)),
                               rtol=1e-5)
    assert float(jnp.linalg.norm(out)) <= clip * 1.001 + 1e-6
    np.testing.assert_allclose(out[:n], x * min(1.0, clip / float(nrm)),
                               rtol=1e-4)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 1, 16, 8, 32),
    (2, 128, 4, 32, 16, 64),
    (2, 256, 2, 64, 64, 128),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    key = jax.random.PRNGKey(s + h)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, n)) / np.sqrt(n)
    cm = jax.random.normal(ks[4], (b, s, n)) / np.sqrt(n)
    yk, sk = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    yr, sr = ssd_scan_ref(x, dt, a, bm, cm, chunk)
    np.testing.assert_allclose(yk, yr, atol=2e-4)
    np.testing.assert_allclose(sk, sr, atol=2e-4)


def test_ssd_scan_bf16_inputs():
    key = jax.random.PRNGKey(9)
    b, s, h, p, n = 1, 128, 2, 32, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = (jax.random.normal(ks[3], (b, s, n)) / 4).astype(jnp.bfloat16)
    cm = (jax.random.normal(ks[4], (b, s, n)) / 4).astype(jnp.bfloat16)
    yk, sk = ssd_scan(x, dt, a, bm, cm, chunk=64)
    yr, sr = ssd_scan_ref(x, dt, a, bm, cm, 64)
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=0.05)


def test_kernel_matches_model_path():
    """models.mamba2.mamba_train(use_kernel=True) == use_kernel=False."""
    import dataclasses
    from repro.configs import reduced_config
    from repro.models import mamba2
    cfg = dataclasses.replace(reduced_config("mamba2-130m"),
                              dtype="float32", param_dtype="float32")
    key = jax.random.PRNGKey(0)
    params, _ = mamba2.mamba_init(key, cfg)
    x = 0.1 * jax.random.normal(key, (2, 64, cfg.d_model))
    y1, c1 = mamba2.mamba_train(params, cfg, x, use_kernel=False)
    y2, c2 = mamba2.mamba_train(params, cfg, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=1e-3)
    np.testing.assert_allclose(c1["ssm"], c2["ssm"], atol=1e-3)
