"""The replint pass itself (DESIGN.md §14): every rule ID fires on its
deliberately-violating fixture in tests/replint_fixtures/, the clean
fixture stays silent, the jaxpr scan sees a callback planted inside a
``lax.scan`` body, the baseline machinery validates and goes stale, and
the real bugs replint found on landing (serve.py key reuse, dp_fedavg's
uncharged spend) stay fixed.
"""
import json
import os
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

import jax
import jax.numpy as jnp

from tools.repro_lint import ledger as rl_ledger
from tools.repro_lint.__main__ import run_ast_checks
from tools.repro_lint.astutil import parse_file
from tools.repro_lint.baseline import (BaselineError, apply_baseline,
                                       load_baseline)
from tools.repro_lint.findings import RULES
from tools.repro_lint.jaxpr_scan import check_jaxpr

FIXTURES = os.path.join(ROOT, "tests", "replint_fixtures")


def _scan(*names, sanctioned=()):
    """Run the AST rules over the named fixture files only, with NO
    sanctioned PRNG dirs (fixtures live under tests/, which the default
    config sanctions for RL102)."""
    files = [parse_file(os.path.join(FIXTURES, n), f"replint_fixtures/{n}")
             for n in names]
    return run_ast_checks(files, sanctioned_prng=sanctioned)


def _rules(findings):
    return {f.rule for f in findings}


# ------------------------------------------------ one fixture per rule ID

@pytest.mark.parametrize("fixture,rule,expect_n", [
    ("rl101_key_reuse.py", "RL101", 2),     # reuse + loop draw
    ("rl102_raw_key.py", "RL102", 1),
    ("rl103_lane_literal.py", "RL103", 2),  # assigned + threaded ks
    ("rl104_dup_tag.py", "RL104", 2),       # dup const + magic literal
    ("rl201_traced_branch.py", "RL201", 1),
    ("rl202_host_coercion.py", "RL202", 1),
    ("rl203_dynamic_shape.py", "RL203", 2),  # nonzero + 1-arg where
    ("rl204_bool_mask.py", "RL204", 1),
    ("rl205_host_callback.py", "RL205", 1),
    ("rl304_uncharged.py", "RL304", 1),
])
def test_rule_fires_on_fixture(fixture, rule, expect_n):
    found = [f for f in _scan(fixture) if f.rule == rule]
    assert len(found) == expect_n, [f.render() for f in found]
    for f in found:
        assert f.path.endswith(fixture)
        assert f.line > 0
        assert RULES[f.rule][0] in f.render()


def test_branch_exclusive_arms_do_not_fire():
    # rl101 fixture's branch_ok draws once per mutually exclusive arm
    found = [f for f in _scan("rl101_key_reuse.py") if f.rule == "RL101"]
    assert not any(f.symbol == "branch_ok" for f in found)


def test_raw_key_sanctioned_dirs_respected():
    # under the DEFAULT config the fixture dir (tests/) is sanctioned
    files = [parse_file(os.path.join(FIXTURES, "rl102_raw_key.py"),
                        "tests/replint_fixtures/rl102_raw_key.py")]
    found = run_ast_checks(files)
    assert not any(f.rule == "RL102" for f in found)


def test_clean_fixture_is_silent():
    found = _scan("clean.py")
    assert found == [], [f.render() for f in found]


# ----------------------------------------------------------- jaxpr scan

def test_jaxpr_scan_flags_callback_in_scan_body():
    def body(c, x):
        y = jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x)
        return c + y, y

    closed = jax.make_jaxpr(
        lambda xs: jax.lax.scan(body, jnp.float32(0), xs))(
        jnp.arange(4, dtype=jnp.float32))
    found = check_jaxpr(closed, "toy-scan")
    assert any(f.rule == "RL206" and "pure_callback" in f.message
               for f in found)
    assert all(f.path == "<jaxpr:toy-scan>" for f in found)


def test_jaxpr_scan_clean_scan():
    closed = jax.make_jaxpr(
        lambda xs: jax.lax.scan(lambda c, x: (c + x, c), jnp.float32(0),
                                xs))(jnp.arange(4, dtype=jnp.float32))
    assert check_jaxpr(closed, "toy-scan") == []


# ----------------------------------------------- registry completeness

class _Rec:
    def __init__(self, **kw):
        self.__dict__.update(kw)


def test_rl301_rl302_injected_registries(tmp_path):
    found = rl_ledger.check_registries(
        str(tmp_path),
        algorithms={"nospend": _Rec(privacy_spend=None),
                    "ok": _Rec(privacy_spend=lambda cfg, b, d=None: 0.1)},
        compressors={"nosens": _Rec(sensitivity=None),
                     "ok": _Rec(sensitivity=lambda cfg, d: 1.0)})
    assert {(f.rule, f.symbol) for f in found} == {
        ("RL301", "nospend"), ("RL302", "nosens")}


def test_rl303_coverage(tmp_path):
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "a_test.py").write_text(
        "CASES = ['covered_alg']\n")
    (tmp_path / "goldens.json").write_text(
        json.dumps({"cases": {"covered_chan-fused": {}}}))
    found = rl_ledger.check_coverage(
        str(tmp_path), goldens_rel="goldens.json", tests_rel="tests",
        names={"algorithm": {"covered_alg": "x.py", "orphan_alg": "x.py"},
               "channel": {"covered_chan": "y.py"}})
    assert [(f.rule, f.symbol) for f in found] == [
        ("RL303", "orphan_alg")]


def test_goldens_schema_guard(tmp_path):
    (tmp_path / "bad.json").write_text("{not json")
    assert rl_ledger.check_goldens_schema(
        str(tmp_path), "bad.json") is not None
    (tmp_path / "nocases.json").write_text("{}")
    assert rl_ledger.check_goldens_schema(
        str(tmp_path), "nocases.json") is not None
    assert rl_ledger.check_goldens_schema(ROOT) is None


# ------------------------------------------------------------- baseline

def test_baseline_suppresses_and_goes_stale(tmp_path):
    bl = tmp_path / "baseline.toml"
    bl.write_text(
        '[[entry]]\nrule = "RL102"\n'
        'path = "replint_fixtures/rl102_raw_key.py"\n'
        'match = "PRNGKey"\nreason = "fixture"\n')
    entries = load_baseline(str(bl))
    findings = _scan("rl102_raw_key.py")
    kept, suppressed, stale = apply_baseline(findings, entries)
    assert stale == [] and len(suppressed) == 1
    assert not any(f.rule == "RL102" for f in kept)
    # the same entry against the clean fixture matches nothing -> stale
    _, _, stale = apply_baseline(_scan("clean.py"), entries)
    assert len(stale) == 1


def test_baseline_schema_errors(tmp_path):
    bad = tmp_path / "b.toml"
    bad.write_text('[[entry]]\nrule = "RL999"\npath = "x"\n'
                   'reason = "?"\n')
    with pytest.raises(BaselineError):
        load_baseline(str(bad))
    bad.write_text('[[entry]]\nrule = "RL101"\npath = "x"\n')
    with pytest.raises(BaselineError):
        load_baseline(str(bad))


def test_repo_baseline_is_valid():
    entries = load_baseline(os.path.join(
        ROOT, "tools", "repro_lint", "baseline.toml"))
    assert entries, "repo baseline should carry the reviewed exceptions"
    assert all(e.reason for e in entries)


# ------------------------------------- regressions for bugs replint found

def test_serve_key_lanes_stay_split():
    """launch/serve.py drew tokens and both embed banks from one key
    (RL101, fixed this PR); the checker must stay silent on it."""
    from tools.repro_lint.prng import check_key_reuse
    path = os.path.join(ROOT, "src", "repro", "launch", "serve.py")
    pf = parse_file(path, "src/repro/launch/serve.py")
    assert check_key_reuse(pf) == []


def test_dp_fedavg_charges_ledger():
    """dp_fedavg injected server-side Gaussian noise but never charged
    the in-graph ledger (RL301, fixed this PR): one round must now spend
    the Thm-1 epsilon of its noise multiplier."""
    import math

    from repro.configs import PFELSConfig
    from repro.fl import Trainer
    from repro.fl.api import replace as state_replace

    cfg = PFELSConfig(num_clients=4, clients_per_round=2, local_steps=1,
                      local_lr=0.1, compression_ratio=0.5, epsilon=2.0,
                      rounds=1, algorithm="dp_fedavg",
                      use_fused_kernel=False)
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((3,), jnp.float32)}
    x = jax.random.normal(key, (4, 8, 3))
    y = jnp.zeros((4, 8), jnp.float32)
    loss_fn = lambda p, b: (jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2),
                            ())

    trainer = Trainer(cfg, loss_fn, params)
    state = state_replace(trainer.init(jax.random.PRNGKey(1)),
                          key=jax.random.PRNGKey(2))
    end, metrics = trainer.run(state, x, y, rounds=1)

    z = cfg.dp_fedavg_sigma * math.sqrt(cfg.clients_per_round)
    expect = math.sqrt(2.0 * math.log(1.25 / cfg.resolved_delta())) / z
    assert int(end.ledger.spends) == 1
    assert float(end.ledger.eps_sum) == pytest.approx(expect, rel=1e-5)
    assert float(metrics["eps_round"][0]) == pytest.approx(expect,
                                                           rel=1e-5)
