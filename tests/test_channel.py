"""Wireless channel model (§4.1, §8.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ChannelConfig
from repro.core import channel


def test_gain_distribution():
    cfg = ChannelConfig()
    g = channel.sample_gains(jax.random.PRNGKey(0), 20000, cfg)
    assert float(g.min()) >= cfg.gain_clip[0] * (1 - 1e-5)  # f32 rounding
    assert float(g.max()) <= cfg.gain_clip[1] * (1 + 1e-5)
    # exponential(0.02) clipped: mean close to 0.02
    assert abs(float(g.mean()) - 0.02) < 0.005


def test_power_limits_match_snr_range():
    cfg = ChannelConfig()
    d = 1000
    p = channel.sample_power_limits(jax.random.PRNGKey(1), 5000, d, cfg)
    snr_db = 10 * jnp.log10(p / (d * cfg.noise_std ** 2))
    assert float(snr_db.min()) >= cfg.snr_db_range[0] - 1e-3
    assert float(snr_db.max()) <= cfg.snr_db_range[1] + 1e-3


def test_noise_std():
    cfg = ChannelConfig(noise_std=2.0)
    z = channel.sample_noise(jax.random.PRNGKey(2), 100000, cfg)
    assert abs(float(z.std()) - 2.0) < 0.05


def test_receive_superposition():
    """y = sum_i |h_i| x_i + z (Eq. 7)."""
    sig = jnp.array([[1.0, 2.0], [3.0, 4.0]])
    gains = jnp.array([0.5, 2.0])
    noise = jnp.array([0.1, -0.1])
    y = channel.receive(sig, gains, noise)
    np.testing.assert_allclose(y, [0.5 + 6 + 0.1, 1 + 8 - 0.1], rtol=1e-6)


# ------------------------------------------- ChannelConfig validation

def test_config_defaults_and_scaled_channel_valid():
    ChannelConfig()
    channel.scaled_channel(10_000)
    channel.scaled_channel(9_750_922)


@pytest.mark.parametrize("bad", [
    dict(gain_clip=(0.1, 1e-4)),      # swapped: used to NaN/flatline beta
    dict(gain_clip=(0.0, 0.1)),       # zero lower bound divides beta
    dict(gain_clip=(-1e-4, 0.1)),
    dict(gain_mean=0.0),
    dict(gain_mean=-0.02),
    dict(noise_std=0.0),              # C2 undefined
    dict(noise_std=-1.0),
    dict(snr_db_range=(15.0, 2.0)),   # unordered
    dict(snr_db_range=(5.0, 5.0)),
    dict(csi_error=-0.1),
    dict(model=""),
    dict(markov_rho=1.0),             # rho=1 never mixes
    dict(markov_rho=-0.1),
    dict(num_antennas=0),
    dict(dropout_prob=1.0),           # every round empty
    dict(dropout_prob=-0.2),
    dict(dropout_base="dropout"),
])
def test_config_rejects_silently_nan_settings(bad):
    with pytest.raises(ValueError):
        ChannelConfig(**bad)


def test_swapped_gain_clip_is_what_validation_prevents():
    """The bug the validation closes: with a swapped clip the old config
    silently pinned every gain to the (tiny) upper bound — here shown on
    the raw primitive with validation bypassed."""
    import dataclasses
    cfg = ChannelConfig()
    g = jnp.clip(jax.random.exponential(jax.random.PRNGKey(0), (64,))
                 * cfg.gain_mean, 0.1, 1e-4)
    assert float(g.max()) <= 1e-4  # every draw collapses to the floor
    with pytest.raises(ValueError, match="gain_clip"):
        dataclasses.replace(cfg, gain_clip=(0.1, 1e-4))
