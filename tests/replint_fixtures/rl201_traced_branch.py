"""Fixture: RL201 — Python branch on a traced value inside the
cohort-core-reachable closure."""
import jax.numpy as jnp


def _build_cohort_core(cfg):
    def cohort_core(x):
        if jnp.sum(x) > 0:
            return x
        return -x
    return cohort_core
