"""Fixture: RL205 — a host numpy op inside reachable code."""
import numpy as np


def _build_cohort_core(cfg):
    def cohort_core(x):
        return np.asarray(x)
    return cohort_core
