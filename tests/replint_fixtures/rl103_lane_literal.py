"""Fixture: RL103 — integer lane subscript on a split_round_key result,
both assignment-derived and via the conventional ``ks`` parameter."""
from repro.fl.rounds import split_round_key


def assigned(key):
    lanes = split_round_key(key)
    return lanes[2]


def threaded(ks):
    return ks[4]
