"""Fixture: RL304 — a root reaching aircomp aggregation with no ledger
charge anywhere on the path."""


def aircomp_aggregate(updates, beta):
    return updates


def run_round(updates, beta):
    return aircomp_aggregate(updates, beta)
