"""Fixture: RL203 — size-less nonzero and 1-arg where (file-wide rule)."""
import jax.numpy as jnp


def support(x):
    return jnp.nonzero(x)


def where_one_arg(x):
    return jnp.where(x > 0)
