"""Fixture: RL202 — float() on a traced value in reachable code."""
import jax.numpy as jnp


def _build_cohort_core(cfg):
    def cohort_core(x):
        return float(jnp.sum(x))
    return cohort_core
