"""Fixture: RL102 — raw PRNGKey construction in library-style code."""
import jax


def make_key():
    return jax.random.PRNGKey(0)
