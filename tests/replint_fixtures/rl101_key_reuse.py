"""Fixture: RL101 — a key consumed twice, and a loop draw without
re-splitting. ``branch_ok`` must NOT fire (mutually exclusive arms)."""
import jax


def draw_twice(key):
    a = jax.random.normal(key, (3,))
    b = jax.random.uniform(key, (3,))
    return a + b


def loop_draw(key):
    total = 0.0
    for _ in range(4):
        total = total + jax.random.normal(key, ())
    return total


def branch_ok(key, flag):
    if flag:
        return jax.random.normal(key, ())
    else:
        return jax.random.uniform(key, ())
