"""Negative fixture: near-miss patterns for every AST rule — correct key
splitting, named lanes, distinct tags, trace-safe reachable code, sized
shape ops, and a charged aircomp path. replint must report NOTHING here.
"""
import jax
import jax.numpy as jnp

from repro.core.privacy import ledger_spend
from repro.fl.rounds import ROUND_KEY_LANES, split_round_key

ALPHA_STREAM_TAG = 0x0101
BETA_STREAM_TAG = 0x0202


def split_then_draw(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (3,))
    b = jax.random.uniform(k2, (3,))
    return a + b


def loop_resplit(key):
    total = jnp.zeros(())
    for _ in range(4):
        key, sub = jax.random.split(key)
        total = total + jax.random.normal(sub, ())
    return total


def folded_redraw(key):
    a = jax.random.normal(key, ())
    key = jax.random.fold_in(key, ALPHA_STREAM_TAG)
    b = jax.random.normal(key, ())
    return a + b


def named_lane(key):
    ks = split_round_key(key)
    return ks[ROUND_KEY_LANES["gains"]]


def sized_support(x, k):
    return jnp.nonzero(x, size=4, fill_value=0)[0]


def _build_cohort_core(cfg):
    def cohort_core(x):
        y = jnp.where(x > 0, x, 0.0)
        return jax.lax.cond(x.shape[0] > 1, lambda: y, lambda: -y)
    return cohort_core


def aircomp_aggregate(updates, beta):
    return updates


def charged_round(updates, beta, ledger):
    out = aircomp_aggregate(updates, beta)
    ledger = ledger_spend(ledger, 0.1)
    return out, ledger
