"""Fixture: RL204 — boolean-mask indexing (file-wide rule)."""


def mask_index(x):
    return x[x > 0]
