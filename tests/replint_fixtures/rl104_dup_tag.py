"""Fixture: RL104 — duplicate stream-tag constants plus a magic literal
shadowing a constant."""
import jax

ALPHA_STREAM_TAG = 0x5151
BETA_STREAM_TAG = 0x5151


def fold(key):
    return jax.random.fold_in(key, 0x5151)
