"""Client-level DP accounting: Theorems 1-3, Lemma 2, composition."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import privacy, randk
from repro.fl.client import local_train, model_update
from jax.flatten_util import ravel_pytree


def test_c2_formula():
    """C2 = 2 sqrt(2) eta tau C1 r sqrt(log(1.25 r/(N delta)))/(N sigma0)."""
    eta, tau, c1, r, n, delta, s0 = 0.05, 5, 1.0, 32, 1000, 1e-3, 1.0
    expect = (2 * math.sqrt(2) * eta * tau * c1 * r
              * math.sqrt(math.log(1.25 * r / (n * delta)))) / (n * s0)
    assert privacy.c2_coefficient(eta, tau, c1, r, n, delta, s0) == \
        pytest.approx(expect)


def test_beta_cap_inverse_of_round_epsilon():
    kw = dict(eta=0.05, tau=5, c1=1.0, r=32, n=1000, delta=1e-3, sigma0=1.0)
    beta = privacy.beta_privacy_cap(1.5, **kw)
    assert privacy.round_epsilon(beta, **kw) == pytest.approx(1.5)


def test_gaussian_mechanism_sigma_matches_thm1():
    psi, eps, delta = 2.0, 0.5, 1e-5
    sigma = privacy.gaussian_mechanism_sigma(psi, eps, delta)
    assert sigma ** 2 >= 2 * math.log(1.25 / delta) * psi ** 2 / eps ** 2 \
        - 1e-9


def test_amplification_monotone_and_below_eps():
    """Thm 2: subsampled epsilon < eps0, increasing in r."""
    eps0 = 0.8
    prev = 0.0
    for r in (1, 10, 100, 1000):
        e = privacy.amplified_epsilon(eps0, r, 1000)
        assert e <= eps0 + 1e-12
        assert e >= prev
        prev = e


def test_composition():
    e_basic, d_basic = privacy.compose_basic(0.1, 1e-5, 100)
    assert e_basic == pytest.approx(10.0)
    e_adv, d_adv = privacy.compose_advanced(0.1, 1e-5, 100)
    assert e_adv < e_basic  # advanced composition is tighter here
    assert d_adv > 100 * 1e-5  # pays delta'


def test_lemma2_sensitivity_empirical():
    """||beta A Delta_e||_2 <= beta eta tau C1 for real local training
    (momentum=0, as in the analysis)."""
    key = jax.random.PRNGKey(0)
    d_in, classes = 10, 3
    params = {"w": jax.random.normal(key, (d_in, classes)) * 0.1,
              "b": jnp.zeros((classes,))}

    def loss_fn(p, batch):
        logits = batch["x"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], 1)[:, 0]
        return jnp.mean(nll), {"accuracy": jnp.zeros(())}

    eta, tau, c1 = 0.1, 4, 0.7
    x = jax.random.normal(key, (40, d_in))
    y = jax.random.randint(key, (40,), 0, classes)
    flat0, unravel = ravel_pytree(params)
    worst = 0.0
    for seed in range(20):
        p_new, _ = local_train(params, x, y, jax.random.PRNGKey(seed),
                               loss_fn=loss_fn, steps=tau, lr=eta, clip=c1,
                               momentum=0.0, batch_size=8)
        delta = ravel_pytree(model_update(params, p_new))[0]
        worst = max(worst, float(jnp.linalg.norm(delta)))
    beta = 2.3
    # Lemma 2: sensitivity of ONE client's contribution
    assert beta * worst <= beta * eta * tau * c1 + 1e-5


def test_ledger():
    led = privacy.PrivacyLedger(n=100, delta=1e-2)
    for _ in range(10):
        led.spend(0.2)
    e, d = led.total_basic()
    assert e == pytest.approx(2.0) and d == pytest.approx(0.1)
    e_adv, _ = led.total_advanced()
    assert e_adv > 0


def test_zcdp_composition_tighter_than_basic():
    """zCDP beats basic composition at many rounds for the same mechanism."""
    z = 2.0   # noise multiplier
    rounds, delta = 500, 1e-5
    eps_zcdp, _ = privacy.compose_zcdp(z, rounds, delta)
    # per-round (eps0, delta) of the same Gaussian (Thm 1 inverse):
    eps0 = math.sqrt(2 * math.log(1.25 / delta)) / z
    eps_basic = eps0 * rounds
    assert eps_zcdp < eps_basic
    # and scales ~sqrt(T): doubling T shouldn't double eps
    eps2, _ = privacy.compose_zcdp(z, 2 * rounds, delta)
    assert eps2 < 1.75 * eps_zcdp


def test_pfels_noise_multiplier():
    z = privacy.pfels_noise_multiplier(2.0, 0.05, 5, 1.0, 1.0)
    assert z == pytest.approx(1.0 / (2.0 * 0.05 * 5))
