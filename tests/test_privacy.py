"""Client-level DP accounting: Theorems 1-3, Lemma 2, composition."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import privacy
from repro.fl.client import local_train, model_update


def test_c2_formula():
    """C2 = 2 sqrt(2) eta tau C1 r sqrt(log(1.25 r/(N delta)))/(N sigma0)."""
    eta, tau, c1, r, n, delta, s0 = 0.05, 5, 1.0, 32, 1000, 1e-3, 1.0
    expect = (2 * math.sqrt(2) * eta * tau * c1 * r
              * math.sqrt(math.log(1.25 * r / (n * delta)))) / (n * s0)
    assert privacy.c2_coefficient(eta, tau, c1, r, n, delta, s0) == \
        pytest.approx(expect)


def test_beta_cap_inverse_of_round_epsilon():
    kw = dict(eta=0.05, tau=5, c1=1.0, r=32, n=1000, delta=1e-3, sigma0=1.0)
    beta = privacy.beta_privacy_cap(1.5, **kw)
    assert privacy.round_epsilon(beta, **kw) == pytest.approx(1.5)


def test_gaussian_mechanism_sigma_matches_thm1():
    psi, eps, delta = 2.0, 0.5, 1e-5
    sigma = privacy.gaussian_mechanism_sigma(psi, eps, delta)
    assert sigma ** 2 >= 2 * math.log(1.25 / delta) * psi ** 2 / eps ** 2 \
        - 1e-9


def test_amplification_monotone_and_below_eps():
    """Thm 2: subsampled epsilon < eps0, increasing in r."""
    eps0 = 0.8
    prev = 0.0
    for r in (1, 10, 100, 1000):
        e = privacy.amplified_epsilon(eps0, r, 1000)
        assert e <= eps0 + 1e-12
        assert e >= prev
        prev = e


def test_composition():
    e_basic, d_basic = privacy.compose_basic(0.1, 1e-5, 100)
    assert e_basic == pytest.approx(10.0)
    e_adv, d_adv = privacy.compose_advanced(0.1, 1e-5, 100)
    assert e_adv < e_basic  # advanced composition is tighter here
    assert d_adv > 100 * 1e-5  # pays delta'


def test_lemma2_sensitivity_empirical():
    """||beta A Delta_e||_2 <= beta eta tau C1 for real local training
    (momentum=0, as in the analysis)."""
    key = jax.random.PRNGKey(0)
    d_in, classes = 10, 3
    params = {"w": jax.random.normal(key, (d_in, classes)) * 0.1,
              "b": jnp.zeros((classes,))}

    def loss_fn(p, batch):
        logits = batch["x"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], 1)[:, 0]
        return jnp.mean(nll), {"accuracy": jnp.zeros(())}

    eta, tau, c1 = 0.1, 4, 0.7
    x = jax.random.normal(key, (40, d_in))
    y = jax.random.randint(key, (40,), 0, classes)
    flat0, unravel = ravel_pytree(params)
    worst = 0.0
    for seed in range(20):
        p_new, _ = local_train(params, x, y, jax.random.PRNGKey(seed),
                               loss_fn=loss_fn, steps=tau, lr=eta, clip=c1,
                               momentum=0.0, batch_size=8)
        delta = ravel_pytree(model_update(params, p_new))[0]
        worst = max(worst, float(jnp.linalg.norm(delta)))
    beta = 2.3
    # Lemma 2: sensitivity of ONE client's contribution
    assert beta * worst <= beta * eta * tau * c1 + 1e-5


def test_ledger():
    led = privacy.PrivacyLedger(n=100, delta=1e-2)
    for _ in range(10):
        led.spend(0.2)
    e, d = led.total_basic()
    assert e == pytest.approx(2.0) and d == pytest.approx(0.1)
    e_adv, _ = led.total_advanced()
    assert e_adv > 0


def test_zcdp_composition_tighter_than_basic():
    """zCDP beats basic composition at many rounds for the same mechanism."""
    z = 2.0   # noise multiplier
    rounds, delta = 500, 1e-5
    eps_zcdp, _ = privacy.compose_zcdp(z, rounds, delta)
    # per-round (eps0, delta) of the same Gaussian (Thm 1 inverse):
    eps0 = math.sqrt(2 * math.log(1.25 / delta)) / z
    eps_basic = eps0 * rounds
    assert eps_zcdp < eps_basic
    # and scales ~sqrt(T): doubling T shouldn't double eps
    eps2, _ = privacy.compose_zcdp(z, 2 * rounds, delta)
    assert eps2 < 1.75 * eps_zcdp


def test_pfels_noise_multiplier():
    z = privacy.pfels_noise_multiplier(2.0, 0.05, 5, 1.0, 1.0)
    assert z == pytest.approx(1.0 / (2.0 * 0.05 * 5))


# ------------------------------------------------------- property tests
# parametrized grids instead of hypothesis (not in the pinned environment)

_BASE = dict(eta=0.05, tau=5, c1=1.0, r=32, n=1000, delta=1e-3, sigma0=1.0)


@pytest.mark.parametrize("eps", [0.1, 0.5, 1.5, 4.0, 10.0])
@pytest.mark.parametrize("scale", [0.5, 1.0, 3.0])
def test_property_round_epsilon_roundtrip(eps, scale):
    """round_epsilon(beta_privacy_cap(eps)) == eps for any C2 > 0."""
    kw = dict(_BASE, eta=_BASE["eta"] * scale)
    beta = privacy.beta_privacy_cap(eps, **kw)
    assert privacy.round_epsilon(beta, **kw) == pytest.approx(eps, rel=1e-9)


@pytest.mark.parametrize("field,values", [
    ("eta", [0.01, 0.05, 0.1, 0.5]),
    ("tau", [1, 2, 5, 20]),
    ("c1", [0.1, 0.5, 1.0, 4.0]),
    ("r", [1, 8, 32, 200]),
])
def test_property_c2_monotone(field, values):
    """C2 is strictly increasing in eta, tau, C1 and r (Eq. 21): a larger
    sensitivity or sampling fraction costs more privacy per unit beta."""
    c2s = [privacy.c2_coefficient(**dict(_BASE, **{field: v}))
           for v in values]
    assert all(b > a for a, b in zip(c2s, c2s[1:])), (field, c2s)


@pytest.mark.parametrize("eps_round", [0.01, 0.1, 0.5])
@pytest.mark.parametrize("rounds", [1, 10, 500])
def test_property_advanced_composition_dominates_single_round(eps_round,
                                                              rounds):
    """T-fold advanced composition never reports less than one round."""
    eps_t, delta_t = privacy.compose_advanced(eps_round, 1e-6, rounds)
    assert eps_t >= eps_round - 1e-12
    assert delta_t >= 1e-6
    # and is monotone in T
    eps_t2, _ = privacy.compose_advanced(eps_round, 1e-6, rounds + 1)
    assert eps_t2 > eps_t


@pytest.mark.parametrize("z", [0.5, 1.0, 2.0, 8.0])
@pytest.mark.parametrize("rounds", [1, 100, 5000])
def test_property_zcdp_finite_positive(z, rounds):
    """compose_zcdp is finite and positive for any valid noise multiplier,
    infinite only at z <= 0."""
    eps, delta = privacy.compose_zcdp(z, rounds, 1e-5)
    assert math.isfinite(eps) and eps > 0
    assert delta == 1e-5
    bad, _ = privacy.compose_zcdp(0.0, rounds, 1e-5)
    assert bad == float("inf")


# ----------------------------- ledger parity across the scenario registry

@pytest.fixture(scope="module")
def _ledger_problem():
    from repro.configs.paper_models import BENCH_MLP
    from repro.data import make_federated_classification
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    x, y, _, _ = make_federated_classification(
        key, n_clients=20, per_client=20, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, (x, y), loss_fn


@pytest.mark.parametrize("backend", ["resident", "streamed"])
def test_property_ledger_matches_host_for_every_channel_model(
        _ledger_problem, backend):
    """For EVERY registered channel model and BOTH bank backends, the
    in-graph ledger equals a host-side PrivacyLedger recomputation from
    the realized per-round betas (``round_epsilon_spent`` uses the
    model's post-combining noise, so the recomputation is the true
    oracle for mimo_mrc too)."""
    from repro.configs import ChannelConfig, PFELSConfig
    from repro.core import channels
    from repro.fl import Trainer, round_epsilon_spent
    from repro.fl.api import replace

    params, (x, y), loss_fn = _ledger_problem
    for model in channels.list_channel_models():
        cfg = PFELSConfig(
            num_clients=20, clients_per_round=4, local_steps=2,
            local_lr=0.05, compression_ratio=0.3, epsilon=2.0, rounds=2,
            bank_backend=backend,
            channel=ChannelConfig(model=model, num_antennas=8,
                                  markov_rho=0.9, dropout_prob=0.3))
        trainer = Trainer(cfg, loss_fn, params)
        state = replace(trainer.init(jax.random.PRNGKey(1)),
                        key=jax.random.PRNGKey(2))
        xs = np.asarray(x) if backend == "streamed" else x
        ys = np.asarray(y) if backend == "streamed" else y
        t = 3
        end, metrics = trainer.run(state, xs, ys, rounds=t)
        host = privacy.PrivacyLedger(n=cfg.num_clients,
                                     delta=cfg.resolved_delta())
        for beta in np.asarray(metrics["beta"]):
            host.spend(min(round_epsilon_spent(cfg, float(beta)),
                           cfg.epsilon))
        totals = trainer.ledger_totals(end)
        np.testing.assert_allclose(totals["basic"], host.total_basic(),
                                   rtol=1e-5, err_msg=model)
        np.testing.assert_allclose(totals["advanced"],
                                   host.total_advanced(), rtol=1e-5,
                                   err_msg=model)
        assert totals["spends"] == t, model
        np.testing.assert_allclose(np.asarray(metrics["eps_round"]),
                                   host.eps_rounds, rtol=1e-6,
                                   err_msg=model)
