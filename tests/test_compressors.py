"""ISSUE 7 property sweep: every entry in the compressor registry
(DESIGN.md §13), across the execution-path matrix.

Three property families, each swept over ``list_compressors()``:

  1. fused == unfused fp32 parity through a full Trainer round, on both
     bank backends (vmapped resident / streamed host loop; the sharded
     cohort path is covered when >= 2 devices are visible) × error
     feedback on/off — the compressor threading (Support.active column,
     encode hook, EF residual via ``compressors.sparsify``) must not
     open a gap between the Pallas kernel path and the reference.
  2. the Theorem-5 per-device energy cap: with the compressor's
     sensitivity factor threaded through β design as C1·s, the expected
     per-device energy (β/g_i^obs)² (k_used/d) (η τ C1 s)² stays <= P_i
     for every registered compressor (Eq. 34c is an expectation
     constraint — the paper's E||x_i||² <= P_i).
  3. the in-graph ledger's ε spend matches a host ``PrivacyLedger``
     recomputation from the realized betas through
     ``round_epsilon_spent`` — which consumes the same sensitivity hook,
     so the power and privacy accounting agree on one C1·s.

Plus registry-contract units (error messages, carry-forced error
feedback, legacy-shim rejection, schedule algebra).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs import CompressionSchedule, PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.core import channel, compressors, privacy
from repro.core.compressors import schedules
from repro.data import make_federated_classification
from repro.fl import (Trainer, make_round_fn, round_epsilon_spent)
from repro.fl.api import replace
from repro.models import cnn

MULTI = len(jax.devices()) >= 2
ALL_COMPRESSORS = compressors.list_compressors()
BACKENDS = ["resident", "streamed"]

BASE = dict(num_clients=12, clients_per_round=4, local_steps=2,
            local_lr=0.05, compression_ratio=0.3, epsilon=2.0, rounds=3)


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    x, y, xt, yt = make_federated_classification(
        key, n_clients=12, per_client=16, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, (x, y), loss_fn


def _cfg(**kw):
    merged = dict(BASE)
    merged.update(kw)
    return PFELSConfig(**merged)


def _state(trainer):
    return replace(trainer.init(jax.random.PRNGKey(1)),
                   key=jax.random.PRNGKey(2))


def _flat(p):
    return np.asarray(ravel_pytree(p)[0])


# ------------------------------------------------ 1. fused/unfused parity

@pytest.mark.parametrize("ef", [False, True], ids=["ef0", "ef1"])
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("comp", ALL_COMPRESSORS)
def test_fused_matches_unfused_through_round(problem, comp, backend, ef):
    """One Trainer.step, fused Pallas kernel vs unfused reference, same
    key: delta_hat, params, energy, β, ε spend, and (with EF) the bank
    residuals agree to fp32 accumulation order — for every compressor on
    both bank backends."""
    params, (x, y), loss_fn = problem
    if backend == "streamed":
        x, y = np.asarray(x), np.asarray(y)
    outs = []
    for fused in (False, True):
        cfg = _cfg(compressor=comp, bank_backend=backend,
                   error_feedback=ef, transmit_clip=0.5 if ef else None,
                   use_fused_kernel=fused)
        tr = Trainer(cfg, loss_fn, params)
        st, m = tr.step(_state(tr), x, y)
        outs.append((st, m))
    (st0, m0), (st1, m1) = outs
    np.testing.assert_allclose(np.asarray(st1.prev_delta),
                               np.asarray(st0.prev_delta),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(_flat(st1.params), _flat(st0.params),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["energy"]), float(m0["energy"]),
                               rtol=1e-5)
    assert float(m1["beta"]) == float(m0["beta"])   # same gains, same min
    np.testing.assert_allclose(float(m1["eps_round"]),
                               float(m0["eps_round"]), rtol=1e-6)
    assert float(m1["subcarriers"]) == float(m0["subcarriers"])
    res0, res1 = st0.bank.residuals, st1.bank.residuals
    if res0 is not None:
        np.testing.assert_allclose(np.asarray(res1), np.asarray(res0),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
@pytest.mark.parametrize("comp", ALL_COMPRESSORS)
def test_sharded_cohort_matches_vmapped(problem, comp):
    """The shard_map cohort path reproduces the vmapped round for every
    compressor (the psum superposition + replicated Support columns)."""
    params, (x, y), loss_fn = problem
    outs = []
    for sharding in ("none", "cohort"):
        cfg = _cfg(compressor=comp, error_feedback=True,
                   transmit_clip=0.5, client_sharding=sharding)
        tr = Trainer(cfg, loss_fn, params)
        st, m = tr.step(_state(tr), x, y)
        outs.append((st, m))
    (st0, m0), (st1, m1) = outs
    np.testing.assert_allclose(np.asarray(st1.prev_delta),
                               np.asarray(st0.prev_delta),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["energy"]), float(m0["energy"]),
                               rtol=1e-5)


# --------------------------------------------- 2. per-device energy cap

@pytest.mark.parametrize("comp", ALL_COMPRESSORS)
def test_property_energy_cap_per_compressor(comp):
    """Eq. 34c with the sensitivity factor: the β the registry designs
    keeps every device's EXPECTED energy
    (β/g_i)² (k_used/d) (η τ C1 s)² <= P_i — for stoch_quant the
    transmitted norm really inflates by s, so dropping the factor from
    the power cap would violate P_i by s²."""
    from repro.fl import algorithms
    d, k, r = 4000, 1200, 8
    cfg = _cfg(compressor=comp, quant_bits=4, compression_ratio=k / d)
    alg = algorithms.get_algorithm("pfels")
    s = compressors.sensitivity_factor(cfg, d)
    ete = cfg.local_lr * cfg.local_steps * cfg.clip * s
    for seed in range(5):
        kg, kp = jax.random.split(jax.random.PRNGKey(seed))
        gains = jnp.abs(0.5 + 0.5 * jax.random.normal(kg, (r,))) + 0.05
        p = channel.sample_power_limits(kp, r, d, cfg.channel)
        beta = alg.design_beta(cfg, gains, p, d, k, c1_scale=s)
        energy = (np.asarray(beta) / np.asarray(gains)) ** 2 \
            * (k / d) * ete ** 2
        assert np.all(energy <= np.asarray(p) * (1 + 1e-5)), comp
    if comp == "stoch_quant":
        # the factor is load-bearing: with the privacy cap out of the way
        # (huge epsilon => power-bound design), dropping c1_scale makes
        # the expected energy of the binding device overshoot its P_i by
        # the s^2 the quantizer really inflates the transmitted norm by
        cfg_hi = _cfg(compressor=comp, quant_bits=4,
                      compression_ratio=k / d, epsilon=1e6)
        beta_raw = alg.design_beta(cfg_hi, gains, p, d, k, c1_scale=1.0)
        energy = (float(beta_raw) / np.asarray(gains)) ** 2 \
            * (k / d) * ete ** 2
        assert np.any(energy > np.asarray(p)), \
            "s=1 design should overshoot P_i for stoch_quant"


# --------------------------------------------- 3. ledger host recompute

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("comp", ALL_COMPRESSORS)
def test_ledger_matches_host_recomputation(problem, comp, backend):
    """state.ledger after run(3) == a host PrivacyLedger fed
    min(round_epsilon_spent(cfg, β_t, d), ε) — round_epsilon_spent
    applies the compressor's sensitivity hook, so this pins the in-graph
    C2' = C2(C1·s) against an independent float64 recomputation."""
    params, (x, y), loss_fn = problem
    if backend == "streamed":
        x, y = np.asarray(x), np.asarray(y)
    cfg = _cfg(compressor=comp, bank_backend=backend, transmit_clip=0.5)
    tr = Trainer(cfg, loss_fn, params)
    st, m = tr.run(_state(tr), x, y, rounds=3)
    host = privacy.PrivacyLedger(cfg.num_clients, cfg.resolved_delta())
    for b in np.asarray(m["beta"]):
        host.spend(min(round_epsilon_spent(cfg, float(b), tr.d),
                       cfg.epsilon))
    eps_host, delta_host = host.total_basic()
    np.testing.assert_allclose(float(st.ledger.eps_sum), eps_host,
                               rtol=1e-5)
    assert int(st.ledger.spends) == 3
    np.testing.assert_allclose(np.asarray(m["eps_round"]),
                               np.asarray(host.eps_rounds), rtol=1e-5)
    if comp == "stoch_quant":
        # the dimension-dependent factor really reached the ledger: the
        # charged eps is s x the rand_k-coefficient recomputation at the
        # same realized beta (capped at cfg.epsilon), with s > 1
        s = compressors.sensitivity_factor(cfg, tr.d)
        assert s > 1.0
        base_cfg = PFELSConfig(**BASE, transmit_clip=0.5)
        for b, er in zip(np.asarray(m["beta"]),
                         np.asarray(m["eps_round"])):
            base = round_epsilon_spent(base_cfg, float(b))
            np.testing.assert_allclose(
                er, min(base * s, cfg.epsilon), rtol=1e-5)


# ----------------------------------------------- registry + schedule units

def test_registry_contract():
    with pytest.raises(KeyError, match="unknown compressor 'nope'"):
        compressors.get_compressor("nope")
    with pytest.raises(ValueError, match="already registered"):
        compressors.register_compressor(
            "rand_k", compressors.get_compressor("rand_k"))
    tmp = compressors.Compressor(
        name="tmp", select_support=lambda cfg, d, k, prev, key:
        compressors.Support(jnp.arange(k)))
    compressors.register_compressor("tmp", tmp)
    try:
        assert "tmp" in compressors.list_compressors()
    finally:
        compressors.unregister_compressor("tmp")
    assert "tmp" not in compressors.list_compressors()


def test_support_helpers():
    d, k = 10, 4
    sup = compressors.Support(jnp.array([1, 3, 5, 7]))
    assert compressors.support_size(sup) == k
    act = jnp.array([1.0, 0.0, 1.0, 0.0])
    sup2 = compressors.and_active(sup, act)
    assert float(compressors.support_size(sup2)) == 2.0
    u = jnp.arange(10, dtype=jnp.float32)
    sp = compressors.sparsify(u, sup2, d)
    np.testing.assert_allclose(
        np.asarray(sp), [0, 1, 0, 0, 0, 5, 0, 0, 0, 0])
    mask = compressors.dense_mask(sup2, d)
    assert float(mask.sum()) == 2.0 and float(mask[1]) == 1.0


def test_carry_compressor_forces_bank_residuals(problem):
    """top_k_ef turns the bank's EF memory on even with
    cfg.error_feedback=False — and actually populates it."""
    params, (x, y), loss_fn = problem
    cfg = _cfg(compressor="top_k_ef", error_feedback=False)
    tr = Trainer(cfg, loss_fn, params)
    st = _state(tr)
    assert st.bank.residuals is not None
    st, _ = tr.step(st, x, y)
    assert float(jnp.abs(st.bank.residuals).sum()) > 0.0


def test_legacy_shims_reject_schedule_and_carry(problem):
    params, (x, y), loss_fn = problem
    d = int(ravel_pytree(params)[0].shape[0])
    unravel = ravel_pytree(params)[1]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(ValueError, match="schedule"):
            make_round_fn(_cfg(schedule=CompressionSchedule(mode="budget")),
                          loss_fn, d, unravel)
        with pytest.raises(ValueError, match="error-feedback"):
            make_round_fn(_cfg(compressor="top_k_ef"), loss_fn, d, unravel)
        # carry + error_feedback=True is fine through the shim
        make_round_fn(_cfg(compressor="top_k_ef", error_feedback=True),
                      loss_fn, d, unravel)


def test_schedule_algebra():
    cfg = _cfg(rounds=5,
               schedule=CompressionSchedule(mode="linear", k_end_ratio=0.5,
                                            power_end=0.6))
    sched = cfg.schedule
    ka0 = schedules.k_active(sched, cfg, 100, 0)
    ka4 = schedules.k_active(sched, cfg, 100, 4)
    assert float(ka0.sum()) == 100.0 and float(ka4.sum()) == 50.0
    np.testing.assert_allclose(float(schedules.power_scale(sched, cfg, 4)),
                               0.6, rtol=1e-6)
    assert schedules.epsilon_round(sched, cfg, 0, 0.0) is None  # not budget
    b = _cfg(rounds=4, epsilon=2.0,
             schedule=CompressionSchedule(mode="budget", eps_floor=0.1))
    # untouched budget paces to eps_total/rounds; the ceiling never
    # exceeds cfg.epsilon and never drops below the floor
    assert float(schedules.epsilon_round(b.schedule, b, 0, 0.0)) == 2.0
    np.testing.assert_allclose(
        float(schedules.epsilon_round(b.schedule, b, 2, 7.0)),
        0.5, rtol=1e-6)
    np.testing.assert_allclose(
        float(schedules.epsilon_round(b.schedule, b, 3, 8.0)),
        0.1, rtol=1e-6)   # floor


def test_budget_schedule_paces_total(problem):
    """mode='budget': the ledger never exceeds ε·rounds, and the
    per-round spend never exceeds the per-round ε (Thm 3 cap intact)."""
    params, (x, y), loss_fn = problem
    cfg = _cfg(rounds=4,
               schedule=CompressionSchedule(mode="budget", eps_floor=0.05))
    tr = Trainer(cfg, loss_fn, params)
    st, m = tr.run(_state(tr), x, y, rounds=4)
    assert np.all(np.asarray(m["eps_round"]) <= cfg.epsilon + 1e-6)
    assert float(st.ledger.eps_sum) <= cfg.epsilon * cfg.rounds + 1e-5


def test_k_anneal_reaches_design_and_receiver(problem):
    """mode='linear' with k_end_ratio<1: the live-slot column shrinks the
    subcarriers metric, relaxes β (sqrt(k) in Eq. 34c), and zeroes the
    reconstruction off the live support."""
    params, (x, y), loss_fn = problem
    cfg = _cfg(rounds=3,
               schedule=CompressionSchedule(mode="linear", k_end_ratio=0.4))
    tr = Trainer(cfg, loss_fn, params)
    st, m = tr.run(_state(tr), x, y, rounds=3)
    sub = np.asarray(m["subcarriers"])
    assert sub[0] > sub[1] > sub[2]
    k_budget = max(int(round(cfg.compression_ratio * tr.d)), 1)
    assert sub[-1] == pytest.approx(0.4 * k_budget, rel=0.01)
    # fewer live subcarriers => weakly larger β under the same gains is
    # not directly comparable across rounds (gains differ); instead the
    # reconstruction must be k_used-sparse
    assert int(np.count_nonzero(np.asarray(st.prev_delta))) <= int(sub[-1])


def test_threshold_compressor_prunes_support(problem):
    """threshold: warm rounds deactivate below-threshold budget slots —
    subcarriers < k budget, delta_hat sparse to the live count."""
    params, (x, y), loss_fn = problem
    cfg = _cfg(compressor="threshold", threshold_frac=0.5)
    tr = Trainer(cfg, loss_fn, params)
    st, m = tr.run(_state(tr), x, y, rounds=3)
    k_budget = max(int(round(cfg.compression_ratio * tr.d)), 1)
    sub = np.asarray(m["subcarriers"])
    assert sub[0] == k_budget          # cold start: all slots live
    assert np.all(sub[1:] < k_budget)  # warm: pruned
    assert np.all(sub >= 1)
    assert int(np.count_nonzero(np.asarray(st.prev_delta))) <= int(sub[-1])


def test_stoch_quant_validation():
    with pytest.raises(ValueError, match="quant_bits"):
        compressors.get_compressor("stoch_quant").sensitivity(
            _cfg(quant_bits=1), 100)
    with pytest.raises(ValueError, match="dimension-dependent"):
        compressors.sensitivity_factor(_cfg(compressor="stoch_quant"),
                                       None)
    # rand_k stays dimension-independent (host callers pass d=None)
    assert compressors.sensitivity_factor(_cfg(), None) == 1.0


def test_stoch_quant_encode_unbiased_and_bounded():
    cfg = _cfg(compressor="stoch_quant", quant_bits=4)
    enc = compressors.get_compressor("stoch_quant").encode
    u = jax.random.normal(jax.random.PRNGKey(3), (1, 256))
    keys = jax.random.split(jax.random.PRNGKey(7), 4096)
    qs = jax.vmap(lambda k: enc(cfg, u, k[None]))(keys)[:, 0, :]
    # unbiased: the mean over rounding draws approaches u (per-draw
    # rounding sd is ||u||/levels/2 ~ 1.1, so se of the mean ~ 0.018)
    np.testing.assert_allclose(np.asarray(qs.mean(0)), np.asarray(u[0]),
                               atol=0.12)
    # deterministic worst-case norm inflation <= the sensitivity factor
    s = compressors.sensitivity_factor(cfg, 256)
    norms = np.linalg.norm(np.asarray(qs), axis=1)
    assert np.all(norms <= s * float(jnp.linalg.norm(u)) * (1 + 1e-5))
