"""rand_k sparsification: Lemma 1 / Lemma 10 identities + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import randk


def test_project_unproject_roundtrip():
    key = jax.random.PRNGKey(0)
    d, k = 100, 30
    x = jax.random.normal(key, (d,))
    idx = randk.sample_indices(key, d, k)
    y = randk.project(x, idx)
    assert y.shape == (k,)
    back = randk.unproject(y, idx, d)
    # exactly k nonzero coords, matching x there
    assert int(jnp.sum(back != 0)) <= k
    np.testing.assert_allclose(back[idx], x[idx], rtol=1e-6)


def test_lemma10_unbiasedness():
    """E[A^T A x] = (k/d) x over the random subset omega."""
    key = jax.random.PRNGKey(1)
    d, k, trials = 64, 16, 3000
    x = jax.random.normal(key, (d,))
    keys = jax.random.split(jax.random.PRNGKey(2), trials)
    sparsified = jax.vmap(
        lambda kk: randk.sparsify(x, randk.sample_indices(kk, d, k), d)
    )(keys)
    mean = jnp.mean(sparsified, axis=0)
    np.testing.assert_allclose(mean, (k / d) * x, atol=0.05)


def test_lemma10_variance():
    """E||A^T A x - x||^2 = (1 - k/d) ||x||^2."""
    key = jax.random.PRNGKey(3)
    d, k, trials = 64, 16, 3000
    x = jax.random.normal(key, (d,))
    keys = jax.random.split(jax.random.PRNGKey(4), trials)
    errs = jax.vmap(
        lambda kk: jnp.sum((randk.sparsify(
            x, randk.sample_indices(kk, d, k), d) - x) ** 2))(keys)
    expected = (1 - k / d) * float(jnp.sum(x ** 2))
    assert abs(float(jnp.mean(errs)) - expected) / expected < 0.05


def test_lemma5_projection_energy():
    """E||A x||^2 = (k/d)||x||^2 (core of Lemma 5)."""
    key = jax.random.PRNGKey(5)
    d, k, trials = 64, 16, 3000
    x = jax.random.normal(key, (d,))
    keys = jax.random.split(jax.random.PRNGKey(6), trials)
    en = jax.vmap(lambda kk: jnp.sum(randk.project(
        x, randk.sample_indices(kk, d, k)) ** 2))(keys)
    expected = (k / d) * float(jnp.sum(x ** 2))
    assert abs(float(jnp.mean(en)) - expected) / expected < 0.05


def test_mask_mode_first_moment_matches_exact():
    """Seeded Bernoulli(p) masks have the same first moment k/d = p."""
    key = jax.random.PRNGKey(7)
    tree = {"a": jnp.ones((50, 20)), "b": jnp.ones((333,))}
    p = 0.3
    total, kept = 0, 0.0
    for i in range(200):
        masks = randk.mask_tree(jax.random.fold_in(key, i), tree, p)
        kept += sum(float(jnp.sum(m)) for m in jax.tree.leaves(masks))
        total += sum(m.size for m in jax.tree.leaves(masks))
    assert abs(kept / total - p) < 0.01


def test_mask_shared_seed_is_deterministic():
    key = jax.random.PRNGKey(8)
    tree = {"w": jnp.zeros((17, 5))}
    m1 = randk.mask_tree(key, tree, 0.5)
    m2 = randk.mask_tree(key, tree, 0.5)
    assert bool(jnp.all(m1["w"] == m2["w"]))


# property test, parametrized over a (d, frac) grid instead of hypothesis
# (not installed in the pinned environment)
@pytest.mark.parametrize("d", [2, 3, 5, 17, 64, 127, 128, 200])
@pytest.mark.parametrize("frac", [0.05, 0.33, 0.71, 1.0])
def test_property_exact_k_selected(d, frac):
    k = max(1, min(d, int(d * frac)))
    idx = randk.sample_indices(jax.random.PRNGKey(d), d, k)
    assert idx.shape == (k,)
    assert len(np.unique(np.asarray(idx))) == k      # without replacement
    assert int(idx.min()) >= 0 and int(idx.max()) < d


def test_lambda_k():
    assert randk.lambda_k(0, 10) == 1.0
    assert randk.lambda_k(10, 10) == 0.0
