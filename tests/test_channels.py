"""The pluggable wireless-scenario registry (DESIGN.md §11): registry
round-trip and validation, the block_fading extraction, Gauss–Markov
round-to-round correlation, multi-antenna MRC combining + post-combining
noise, Bernoulli dropout (realized-r β design, error-feedback retention),
cross-backend state carriage, and checkpointing of stateful models."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro import checkpoint
from repro.configs import ChannelConfig, PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.core import channel, channels
from repro.core.channels import (ChannelModel, ChannelRound, design_gains,
                                 effective_noise_std, get_channel_model,
                                 list_channel_models, observed_gains,
                                 realized_cohort_size,
                                 register_channel_model,
                                 unregister_channel_model)
from repro.fl import Trainer, make_round_fn
from repro.fl.api import replace
from repro.models import cnn

BASE = dict(num_clients=20, clients_per_round=4, local_steps=2,
            local_lr=0.05, compression_ratio=0.3, epsilon=2.0, rounds=2)


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    from repro.data import make_federated_classification
    x, y, _, _ = make_federated_classification(
        key, n_clients=20, per_client=20, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, (x, y), loss_fn


def _trainer(cfg, problem):
    params, _, loss_fn = problem
    trainer = Trainer(cfg, loss_fn, params)
    state = replace(trainer.init(jax.random.PRNGKey(1)),
                    key=jax.random.PRNGKey(2))
    return trainer, state


def _flat(p):
    return ravel_pytree(p)[0]


# ------------------------------------------------------------- registry

def test_builtin_models_registered():
    assert set(list_channel_models()) >= {
        "block_fading", "markov_fading", "mimo_mrc", "dropout"}


def test_registry_unknown_and_duplicate():
    with pytest.raises(KeyError, match="unknown channel model"):
        get_channel_model("no_such_channel")
    with pytest.raises(ValueError, match="already registered"):
        register_channel_model(
            "block_fading", get_channel_model("block_fading"))
    with pytest.raises(ValueError, match="needs init, step"):
        register_channel_model("broken", ChannelModel(
            name="broken", init=None, step=None, noise_std=None))


def test_registry_round_trip(problem):
    """A registered toy scenario is a first-class ChannelConfig.model:
    constant gains make β deterministic through a full Trainer round."""
    const_gain = 0.05

    def _step(carry, cfg, r, sel, gains_key, csi_key):
        return carry, ChannelRound(
            gains=jnp.full((r,), const_gain, jnp.float32))

    register_channel_model("toy_constant", ChannelModel(
        name="toy_constant",
        init=lambda key, n, cfg: None,
        step=_step,
        noise_std=lambda cfg: cfg.noise_std))
    try:
        cfg = PFELSConfig(**BASE, channel=ChannelConfig(
            model="toy_constant"))
        trainer, state = _trainer(cfg, problem)
        x, y = problem[1]
        end, m = trainer.run(state, x, y, rounds=2)
        assert bool(jnp.all(jnp.isfinite(m["train_loss"])))
        # beta = min_i g sqrt(d P_i)/(C1 eta tau sqrt(k)) capped by eps/C2
        from repro.core import power_control, privacy
        d = trainer.d
        k = max(int(round(cfg.compression_ratio * d)), 1)
        cap_pop = float(power_control.beta_power_cap(
            jnp.full((cfg.num_clients,), const_gain), state.power_limits,
            d, k, cfg.clip, cfg.local_lr, cfg.local_steps))
        cap_priv = privacy.beta_privacy_cap(
            cfg.epsilon, cfg.local_lr, cfg.local_steps, cfg.clip,
            cfg.clients_per_round, cfg.num_clients, cfg.resolved_delta(),
            cfg.channel.noise_std)
        assert float(m["beta"][0]) >= min(cap_pop, cap_priv) - 1e-5
        assert float(m["beta"][0]) <= cap_priv + 1e-5
    finally:
        unregister_channel_model("toy_constant")


# ---------------------------------------------------------- block_fading

def test_block_fading_step_is_the_extracted_sampler():
    """The registry entry draws exactly what the pre-registry round body
    drew: sample_gains on the gains lane, estimate_gains on the csi lane
    (skipped — obs None — under perfect CSI)."""
    model = get_channel_model("block_fading")
    gk, ck = jax.random.split(jax.random.PRNGKey(7))
    cfg = ChannelConfig()
    carry, cr = model.step(None, cfg, 16, None, gk, ck)
    assert carry is None and cr.tx_mask is None and cr.gains_obs is None
    assert bool(jnp.array_equal(cr.gains,
                                channel.sample_gains(gk, 16, cfg)))
    cfg_csi = ChannelConfig(csi_error=0.3)
    _, cr2 = model.step(None, cfg_csi, 16, None, gk, ck)
    assert bool(jnp.array_equal(
        cr2.gains_obs, channel.estimate_gains(ck, cr2.gains, cfg_csi)))
    assert effective_noise_std(cfg) == cfg.noise_std


# --------------------------------------------------------- markov_fading

def test_markov_marginal_matches_block_fading_law():
    """The Gaussian-copula construction keeps the paper's per-round
    marginal: clipped Exp(gain_mean), same mean as block_fading."""
    cfg = ChannelConfig(model="markov_fading", markov_rho=0.8)
    model = get_channel_model("markov_fading")
    n = 20000
    carry = model.init(jax.random.PRNGKey(0), n, cfg)
    carry, cr = model.step(carry, cfg, n, jnp.arange(n),
                           jax.random.PRNGKey(1), jax.random.PRNGKey(2))
    g = cr.gains
    assert float(g.min()) >= cfg.gain_clip[0] * (1 - 1e-5)
    assert float(g.max()) <= cfg.gain_clip[1] * (1 + 1e-5)
    assert abs(float(g.mean()) - cfg.gain_mean) < 0.005


@pytest.mark.parametrize("rho", [0.0, 0.5, 0.95])
def test_markov_round_to_round_correlation(rho):
    """Consecutive-round gains correlate ~rho (0 -> i.i.d. like
    block_fading); correlation is monotone in markov_rho."""
    cfg = ChannelConfig(model="markov_fading", markov_rho=rho)
    model = get_channel_model("markov_fading")
    n = 8000
    sel = jnp.arange(n)
    carry = model.init(jax.random.PRNGKey(0), n, cfg)
    carry, cr1 = model.step(carry, cfg, n, sel,
                            jax.random.PRNGKey(1), jax.random.PRNGKey(2))
    carry, cr2 = model.step(carry, cfg, n, sel,
                            jax.random.PRNGKey(3), jax.random.PRNGKey(4))
    c = np.corrcoef(np.asarray(cr1.gains), np.asarray(cr2.gains))[0, 1]
    # the copula transform attenuates the latent correlation a bit
    assert c == pytest.approx(rho, abs=0.12)


def test_markov_state_lives_in_trainstate_and_checkpoints(problem):
    """The (n,) latent carry joins TrainState, advances across run() and
    chunked resume, survives pytree flatten and checkpoint round-trip."""
    cfg = PFELSConfig(**BASE, channel=ChannelConfig(
        model="markov_fading", markov_rho=0.9))
    trainer, state = _trainer(cfg, problem)
    x, y = problem[1]
    assert state.chan.shape == (cfg.num_clients,)
    s1, _ = trainer.run(state, x, y, rounds=2)
    assert not bool(jnp.array_equal(s1.chan, state.chan))
    # resume: run(2)+run(1) == run(3) is NOT required (independent key
    # schedules), but the carry must keep evolving
    s2, _ = trainer.run(s1, x, y, rounds=1)
    assert not bool(jnp.array_equal(s2.chan, s1.chan))
    # pytree + checkpoint round-trip
    leaves, treedef = jax.tree.flatten(s1)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert bool(jnp.array_equal(rebuilt.chan, s1.chan))
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck")
        checkpoint.save_train_state(path, s1)
        restored = checkpoint.restore_train_state(path, trainer.init(
            jax.random.PRNGKey(1)))
        assert bool(jnp.array_equal(restored.chan, s1.chan))
        # and training continues bit-identically from the restored state
        a, _ = trainer.run(s1, x, y, rounds=1)
        b, _ = trainer.run(restored, x, y, rounds=1)
        assert bool(jnp.array_equal(_flat(a.params), _flat(b.params)))


def test_stateful_model_rejected_by_legacy_shims(problem):
    params, _, loss_fn = problem
    d = _flat(params).shape[0]
    cfg = PFELSConfig(**BASE, channel=ChannelConfig(model="markov_fading"))
    with pytest.raises(ValueError, match="stateful"), \
            pytest.deprecated_call():
        make_round_fn(cfg, loss_fn, d, lambda f: params)


# ------------------------------------------------------------- mimo_mrc

def test_mimo_combining_and_array_gain():
    """Effective gain = sum over per-antenna magnitudes; M=1 reduces
    bitwise to block_fading; the mean effective gain scales ~M."""
    model = get_channel_model("mimo_mrc")
    gk, ck = jax.random.split(jax.random.PRNGKey(3))
    cfg1 = ChannelConfig(model="mimo_mrc", num_antennas=1)
    _, cr1 = model.step(None, cfg1, 4096, None, gk, ck)
    assert bool(jnp.allclose(cr1.gains,
                             channel.sample_gains(gk, 4096, cfg1)))
    cfg8 = ChannelConfig(model="mimo_mrc", num_antennas=8)
    _, cr8 = model.step(None, cfg8, 4096, None, gk, ck)
    ratio = float(cr8.gains.mean()) / float(cr1.gains.mean())
    assert ratio == pytest.approx(8.0, rel=0.1)


def test_mimo_post_combining_noise_feeds_privacy():
    """sigma_eff = sqrt(M) sigma_0, and the per-round ε ledger charge is
    computed against it (Thm 3's C2 is ∝ 1/sigma)."""
    from repro.fl.rounds import round_epsilon_spent

    cfg1 = PFELSConfig(**BASE)
    cfg8 = dataclasses.replace(cfg1, channel=ChannelConfig(
        model="mimo_mrc", num_antennas=8))
    assert effective_noise_std(cfg8.channel) == pytest.approx(
        np.sqrt(8.0) * cfg8.channel.noise_std)
    beta = 3.0
    # same beta costs sqrt(M)x LESS epsilon under the combined noise
    assert round_epsilon_spent(cfg8, beta) == pytest.approx(
        round_epsilon_spent(cfg1, beta) / np.sqrt(8.0), rel=1e-6)


# -------------------------------------------------------------- dropout

def test_dropout_mask_and_realized_r():
    model = get_channel_model("dropout")
    cfg = ChannelConfig(model="dropout", dropout_prob=0.5)
    gk, ck = jax.random.split(jax.random.PRNGKey(5))
    _, cr = model.step(None, cfg, 4096, None, gk, ck)
    m = np.asarray(cr.tx_mask)
    assert set(np.unique(m)) <= {0.0, 1.0}
    assert m.mean() == pytest.approx(0.5, abs=0.05)
    assert float(realized_cohort_size(cr, 4096)) == m.sum()
    # the base model's gain stream is untouched by the mask draw
    base_cfg = ChannelConfig()
    assert bool(jnp.array_equal(
        cr.gains, channel.sample_gains(gk, 4096, base_cfg)))


def test_dropout_design_gains_lift_dropped_clients():
    """β-design mins over REALIZED transmitters only: a dropped client
    with the worst channel must not bind the power cap."""
    cr = ChannelRound(gains=jnp.array([1e-4, 0.05, 0.08]),
                      tx_mask=jnp.array([0.0, 1.0, 1.0]))
    g = design_gains(cr)
    assert float(g[0]) == float(np.float32(channels.DESIGN_GAIN_BIG))
    assert bool(jnp.array_equal(g[1:], cr.gains[1:]))
    assert float(realized_cohort_size(cr, 3)) == 2.0
    # observed_gains without CSI is the true gains
    assert bool(jnp.array_equal(observed_gains(cr), cr.gains))


def test_dropout_error_feedback_keeps_dropped_update(problem):
    """A dropped client transmitted nothing, so with error feedback its
    ENTIRE (pre-sparsification) update stays in its residual memory —
    verified exactly by recomputing the cohort's updates and the
    Bernoulli mask from the documented PRNG lanes."""
    import functools

    from repro.fl import rounds as rounds_mod
    from repro.fl.client import local_train, model_update
    from repro.core.channels import dropout as dropout_mod

    params, (x, y), loss_fn = problem
    cfg = PFELSConfig(**BASE, error_feedback=True,
                      channel=ChannelConfig(model="dropout",
                                            dropout_prob=0.5))
    trainer, state = _trainer(cfg, problem)
    r = cfg.clients_per_round
    for _ in range(8):
        ks = rounds_mod.split_round_key(state.key)
        new_state, m = trainer.step(state, x, y)
        r_real = float(m["r_realized"])
        assert 0.0 <= r_real <= r
        if r_real in (0.0, float(r)):
            state = new_state
            continue
        # recompute the round from the pinned lanes (DESIGN.md §5/§11)
        sel = np.asarray(rounds_mod.sample_cohort(ks[0],
                                                  cfg.num_clients, r))
        ck = jax.random.split(ks[1], r)
        keep = np.asarray(jax.random.bernoulli(
            jax.random.fold_in(ks[2], dropout_mod._MASK_TAG),
            1.0 - cfg.channel.dropout_prob, (r,)))
        train = functools.partial(
            local_train, loss_fn=loss_fn, steps=cfg.local_steps,
            lr=cfg.local_lr, clip=cfg.clip, momentum=cfg.momentum)
        new_p, _ = jax.vmap(lambda cx, cy, k: train(state.params, cx, cy,
                                                    k))(x[sel], y[sel], ck)
        flat = np.asarray(jax.vmap(
            lambda p_: _flat(model_update(state.params, p_)))(new_p))
        # EF adds the previous residual before sparsification
        flat = flat + np.asarray(state.bank.residuals)[sel]
        res = np.asarray(new_state.bank.residuals)[sel]
        for i in range(r):
            if keep[i]:
                # a kept client transmitted its (sparse) support: its
                # residual lost something relative to the full update
                assert np.linalg.norm(res[i] - flat[i]) > 1e-8, i
            else:
                # a dropped client keeps its ENTIRE update
                np.testing.assert_allclose(res[i], flat[i], rtol=1e-5,
                                           atol=1e-8, err_msg=str(i))
        return
    pytest.skip("no partially-dropped round sampled in 8 tries")


def test_dropout_all_dropped_round_is_finite(problem):
    """Even an all-dropped round (r_realized = 0) reconstructs a finite
    (noise-only) update — the realized-r floor and the finite design
    lift."""
    cfg = PFELSConfig(**BASE, channel=ChannelConfig(
        model="dropout", dropout_prob=0.97))
    trainer, state = _trainer(cfg, problem)
    x, y = problem[1]
    end, m = trainer.run(state, x, y, rounds=4)
    assert float(np.asarray(m["r_realized"]).min()) == 0.0
    assert bool(jnp.all(jnp.isfinite(_flat(end.params))))
    assert bool(jnp.all(jnp.isfinite(m["beta"])))


def test_dropout_all_dropped_digital_round_is_a_noop(problem):
    """An all-dropped round under a DIGITAL scheme received nothing and
    must apply NO update — in particular dp_fedavg must not take an
    r-fold noise-amplified pure-noise step."""
    cfg = PFELSConfig(**BASE, algorithm="dp_fedavg",
                      channel=ChannelConfig(model="dropout",
                                            dropout_prob=0.97))
    trainer, state = _trainer(cfg, problem)
    x, y = problem[1]
    seen_empty = False
    for _ in range(5):
        before = _flat(state.params)
        state, m = trainer.step(state, x, y)
        if float(m["r_realized"]) == 0.0:
            assert bool(jnp.array_equal(_flat(state.params), before))
            seen_empty = True
    assert seen_empty


def test_dropout_fused_matches_unfused_division(problem):
    """The fused aggregate under a mask divides by realized r directly
    (not a post-correction), so fused-vs-unfused stays within the usual
    fp32 accumulation-order tolerance under dropout too."""
    from repro.core import aggregation, randk

    r, d, k = 4, 512, 128
    key = jax.random.PRNGKey(8)
    u = jax.random.normal(key, (r, d))
    gains = jnp.array([0.02, 0.05, 0.03, 0.04])
    mask = jnp.array([1.0, 0.0, 1.0, 1.0])
    idx = randk.sample_indices(key, d, k)
    kw = dict(d=d, sigma0=1.0, r=r, tx_mask=mask)
    d_ref, e_ref, _ = aggregation.aircomp_aggregate(
        u, idx, gains, 2.0, key, **kw)
    d_fus, e_fus, _ = aggregation.aircomp_aggregate_fused(
        u, idx, gains, 2.0, key, use_kernel=False, **kw)
    # the fp32 accumulation-order tier (DESIGN.md §5) — the same bound
    # the maskless fused-vs-unfused pair meets
    np.testing.assert_allclose(np.asarray(d_fus), np.asarray(d_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(e_fus), float(e_ref), rtol=1e-6)


def test_dropout_digital_aggregation_averages_realized_cohort(problem):
    """fedavg under dropout must average over the updates actually
    RECEIVED (realized r), not the nominal cohort — recomputed from the
    documented PRNG lanes (DESIGN.md §5/§11)."""
    import functools

    from repro.fl import rounds as rounds_mod
    from repro.fl.client import local_train, model_update
    from repro.core.channels import dropout as dropout_mod

    params, (x, y), loss_fn = problem
    cfg = PFELSConfig(**BASE, algorithm="fedavg",
                      channel=ChannelConfig(model="dropout",
                                            dropout_prob=0.5))
    trainer, state = _trainer(cfg, problem)
    for _ in range(6):
        prev_params = state.params
        ks = rounds_mod.split_round_key(state.key)
        state, m = trainer.step(state, x, y)
        r = cfg.clients_per_round
        r_real = float(m["r_realized"])
        if r_real in (0.0, float(r)):
            continue
        # recompute the masked mean from the pinned lanes
        sel = rounds_mod.sample_cohort(ks[0], cfg.num_clients, r)
        ck = jax.random.split(ks[1], r)
        train = functools.partial(
            local_train, loss_fn=loss_fn, steps=cfg.local_steps,
            lr=cfg.local_lr, clip=cfg.clip, momentum=cfg.momentum)
        new_p, _ = jax.vmap(lambda cx, cy, k: train(prev_params, cx, cy,
                                                    k))(x[sel], y[sel], ck)
        flat = jax.vmap(lambda p_: _flat(model_update(prev_params, p_)))(
            new_p)
        keep = jax.random.bernoulli(
            jax.random.fold_in(ks[2], dropout_mod._MASK_TAG),
            1.0 - cfg.channel.dropout_prob, (r,)).astype(jnp.float32)
        expect = jnp.sum(flat * keep[:, None], axis=0) / jnp.sum(keep)
        got = _flat(state.params) - _flat(prev_params)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   rtol=1e-5, atol=1e-7)
        return
    pytest.skip("no partially-dropped round sampled in 6 tries")


def test_dropout_wraps_stateful_base(problem):
    """dropout over markov_fading composes: carry evolves, mask applies,
    and the wrapper reports the base's statefulness."""
    chan = ChannelConfig(model="dropout", dropout_base="markov_fading",
                         dropout_prob=0.3, markov_rho=0.9)
    assert get_channel_model("dropout").stateful(chan)
    cfg = PFELSConfig(**BASE, channel=chan)
    trainer, state = _trainer(cfg, problem)
    x, y = problem[1]
    assert state.chan.shape == (cfg.num_clients,)
    end, m = trainer.run(state, x, y, rounds=2)
    assert not bool(jnp.array_equal(end.chan, state.chan))
    assert bool(jnp.all(jnp.isfinite(m["train_loss"])))


def test_dropout_cannot_wrap_itself():
    with pytest.raises(ValueError, match="self-nesting"):
        ChannelConfig(model="dropout", dropout_base="dropout")


# ------------------------------------------- cross-model Trainer contract

@pytest.mark.parametrize("model", sorted(
    set(channels.list_channel_models())))
def test_every_model_runs_both_backends_bit_identically(model, problem):
    """Resident-scan vs streamed-host-loop bit parity for EVERY registered
    model — the channel carry takes the same lanes and ops under both
    (DESIGN.md §11 parity rule)."""
    chan = ChannelConfig(model=model)
    cfg_r = PFELSConfig(**BASE, channel=chan)
    cfg_s = dataclasses.replace(cfg_r, bank_backend="streamed")
    tr, sr = _trainer(cfg_r, problem)
    ts, ss = _trainer(cfg_s, problem)
    x, y = problem[1]
    sr, mr = tr.run(sr, x, y, rounds=2)
    ss, ms = ts.run(ss, np.asarray(x), np.asarray(y), rounds=2)
    assert bool(jnp.array_equal(_flat(sr.params), _flat(ss.params)))
    if sr.chan is None:
        assert ss.chan is None
    else:
        assert bool(jnp.array_equal(sr.chan, ss.chan))
    for k in mr:
        assert bool(jnp.array_equal(mr[k], jnp.asarray(ms[k]))), k


def test_metric_contract_uniform_across_models(problem):
    """Every channel model reports the same metric keys (r_realized
    included) — the fixed Trainer metrics contract extends over the
    scenario axis."""
    x, y = problem[1]
    keysets = set()
    for model in channels.list_channel_models():
        cfg = PFELSConfig(**BASE, channel=ChannelConfig(model=model))
        trainer, state = _trainer(cfg, problem)
        _, m = trainer.step(state, x, y)
        keysets.add(frozenset(m))
        if model != "dropout":
            assert float(m["r_realized"]) == cfg.clients_per_round
    assert len(keysets) == 1
