"""Per-architecture smoke tests (REQUIRED): a reduced variant of each
assigned family runs one forward/train step on CPU with correct shapes and
no NaNs; decode matches prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, reduced_config
from repro.models import transformer as T


def _batch_for(cfg, key, b=2, s=32):
    s_text = s - cfg.vision_prefix if cfg.family == "vlm" else s
    batch = {
        "tokens": jax.random.randint(key, (b, s_text), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s_text), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.vision_prefix, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["audio_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = reduced_config(arch)
    assert cfg.n_layers <= 2 * len(cfg.block_pattern)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(key, cfg)
    batch = _batch_for(cfg, key)

    @jax.jit
    def step(p, b):
        (loss, m), g = jax.value_and_grad(
            lambda pp: T.forward_train(pp, cfg, b), has_aux=True)(p)
        return loss, g

    loss, grads = step(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    flat = jax.tree.leaves(grads)
    assert all(g.shape == p.shape for g, p in
               zip(flat, jax.tree.leaves(params)))
    assert not any(bool(jnp.any(jnp.isnan(g))) for g in flat), arch


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "granite-moe-3b-a800m",
                                  "zamba2-2.7b", "mamba2-130m",
                                  "whisper-tiny", "qwen2-vl-72b"])
def test_prefill_decode_consistency(arch):
    cfg = dataclasses.replace(reduced_config(arch), dtype="float32",
                              param_dtype="float32")
    key = jax.random.PRNGKey(1)
    params, _ = T.init_params(key, cfg)
    b, s = 2, 24
    batch = _batch_for(cfg, key, b, s)
    batch.pop("labels")
    toks = batch["tokens"]
    logits_full, _, _ = T.prefill(params, cfg, batch, extra_slots=2)
    batch2 = dict(batch, tokens=toks[:, :-1])
    _, caches, enc = T.prefill(params, cfg, batch2, extra_slots=2)
    logits_dec, _ = T.decode_step(params, cfg, toks[:, -1:], caches,
                                  enc_out=enc)
    err = float(jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, 0])))
    assert err < 1e-3, (arch, err)


def test_sliding_window_matches_full_when_window_covers():
    """window >= S must equal full attention."""
    cfg = dataclasses.replace(reduced_config("phi3-mini-3.8b"),
                              dtype="float32", param_dtype="float32")
    key = jax.random.PRNGKey(2)
    params, _ = T.init_params(key, cfg)
    batch = _batch_for(cfg, key, 2, 16)
    l1, _ = T.forward_train(params, cfg, batch, window=None)
    l2, _ = T.forward_train(params, cfg, batch, window=64)
    assert float(jnp.abs(l1 - l2)) < 1e-4


def test_sliding_window_decode_ring_buffer():
    """Decode beyond the window: ring buffer stays consistent with a full
    forward restricted to the window."""
    cfg = dataclasses.replace(reduced_config("phi3-mini-3.8b"),
                              dtype="float32", param_dtype="float32")
    key = jax.random.PRNGKey(3)
    params, _ = T.init_params(key, cfg)
    window = 8
    b, s = 1, 20
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    # windowed full forward over all tokens
    logits_fullfwd, _, _ = T.prefill(params, cfg,
                                     {"tokens": toks}, window=window)
    # prefill w tokens then ring-decode the rest
    from repro.models import transformer as TT
    caches = TT.make_caches(cfg, b, window, window=window,
                            dtype=jnp.float32)
    # decode token by token from scratch
    logits = None
    for i in range(s):
        logits, caches = T.decode_step(params, cfg, toks[:, i:i + 1],
                                       caches, window=window)
    err = float(jnp.max(jnp.abs(logits_fullfwd[:, -1] - logits[:, 0])))
    assert err < 1e-3, err
