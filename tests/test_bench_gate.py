"""Unit tests for the perf-regression gate (tools/check_bench.py) and the
trajectory emitter (benchmarks.kernel_bench.emit) on SYNTHETIC
trajectories — no benchmark actually runs here.

The gate's contract (DESIGN.md §12): pinned rows compare fused/oracle
RATIOS between the committed baseline and a fresh candidate, so machine
speed cancels; a vanished pinned row is a hard failure; a schema-version
mismatch is an actionable exit-2 error, never a silent pass.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tools"))
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))))

import check_bench


def _row(op, us, oracle=None, pinned=False, **extra):
    r = {"op": op, "config": "synthetic", "us_per_call": us,
         "oracle_us_per_call": oracle, "pinned": pinned}
    r.update(extra)
    return r


def _doc(rows, schema=check_bench.SCHEMA_VERSION):
    return {"schema_version": schema, "meta": {"synthetic": True},
            "rows": rows}


BASE = _doc([
    _row("scenario_dropout_vmapped_fused", 100.0, oracle=120.0,
         pinned=True),
    _row("scenario_dropout_vmapped_unfused", 120.0),
    _row("rounds_trainer_run", 500.0),  # unpinned: never gated
])


def _dump(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_identical_trajectory_passes():
    assert check_bench.check(BASE, BASE, tolerance=0.25) == 0


def test_synthetic_2x_slowdown_fails(tmp_path, capsys):
    """The ISSUE-6 acceptance criterion: doubling a pinned row's time
    (oracle unchanged) doubles its ratio and must fail the gate —
    end-to-end through main() so the exit code is exercised too."""
    cand = _doc([
        _row("scenario_dropout_vmapped_fused", 200.0, oracle=120.0,
             pinned=True),
        _row("scenario_dropout_vmapped_unfused", 120.0),
    ])
    rc = check_bench.main([
        "--candidate", _dump(tmp_path, "cand.json", cand),
        "--baseline", _dump(tmp_path, "base.json", BASE)])
    assert rc == 1
    assert "FAIL scenario_dropout_vmapped_fused" in capsys.readouterr().out


def test_machine_speed_cancels():
    """A uniformly 10x slower machine keeps every ratio — no failure."""
    cand = _doc([
        _row("scenario_dropout_vmapped_fused", 1000.0, oracle=1200.0,
             pinned=True),
        _row("scenario_dropout_vmapped_unfused", 1200.0),
    ])
    assert check_bench.check(cand, BASE, tolerance=0.25) == 0


def test_tolerance_respected():
    """A 20% ratio regression passes at tol=0.25 and fails at tol=0.1."""
    cand = _doc([_row("scenario_dropout_vmapped_fused", 120.0,
                      oracle=120.0, pinned=True)])
    assert check_bench.check(cand, BASE, tolerance=0.25) == 0
    assert check_bench.check(cand, BASE, tolerance=0.10) == 1


def test_per_row_tolerance_overrides_global():
    base = _doc([_row("op_a", 100.0, oracle=100.0, pinned=True,
                      tolerance=0.5)])
    cand = _doc([_row("op_a", 140.0, oracle=100.0, pinned=True)])
    # global tol=0.1 would fail, but the baseline row carries tol=0.5
    assert check_bench.check(cand, base, tolerance=0.10) == 0
    cand2 = _doc([_row("op_a", 160.0, oracle=100.0, pinned=True)])
    assert check_bench.check(cand2, base, tolerance=0.10) == 1


def test_missing_pinned_row_hard_fails(capsys):
    """Renaming (or dropping) a pinned row without refreshing the
    committed trajectory must fail, not silently skip the gate."""
    cand = _doc([
        _row("scenario_dropout_vmapped_fused_RENAMED", 100.0,
             oracle=120.0, pinned=True)])
    assert check_bench.check(cand, BASE, tolerance=0.25) >= 1
    assert "missing from" in capsys.readouterr().out


def test_new_pinned_row_is_not_a_failure(capsys):
    cand = _doc(BASE["rows"] + [_row("op_new", 10.0, oracle=20.0,
                                     pinned=True)])
    assert check_bench.check(cand, BASE, tolerance=0.25) == 0
    assert "new  op_new" in capsys.readouterr().out


def test_schema_mismatch_is_actionable(tmp_path, capsys):
    rc = check_bench.main([
        "--candidate", _dump(tmp_path, "cand.json", _doc([], schema=999)),
        "--baseline", _dump(tmp_path, "base.json", BASE)])
    assert rc == 2
    err = capsys.readouterr().err
    assert "schema_version" in err and "regenerate" in err


def test_malformed_file_exits_2(tmp_path):
    p = tmp_path / "junk.json"
    p.write_text("{not json")
    rc = check_bench.main(["--candidate", str(p),
                           "--baseline", str(p)])
    assert rc == 2


def test_pinned_row_without_oracle_rejected():
    doc = _doc([_row("op_a", 100.0, oracle=None, pinned=True)])
    with pytest.raises(check_bench.BenchFormatError):
        check_bench.pinned_ratios(doc, "<synthetic>")


def test_committed_trajectory_loads_and_self_checks():
    """The committed BENCH_*.json must satisfy its own gate exactly."""
    path = check_bench.newest_baseline()
    doc = check_bench.load(path)
    assert check_bench.check(doc, doc, tolerance=0.0,
                             cand_path=path, base_path=path) == 0
    pinned = [r for r in doc["rows"] if r.get("pinned")]
    assert pinned, f"{path} pins no rows — the gate would gate nothing"


def test_emit_pairs_pinned_rows_with_oracles(tmp_path):
    """kernel_bench.emit joins each pinned row to its oracle's us/call
    and refuses to write a trajectory that splits a pinned/oracle pair."""
    from benchmarks import kernel_bench

    rows = [("pfels_transmit_fused_pallas", 50.0, "r=16"),
            ("pfels_transmit_unfused", 80.0, "r=16")]
    out = str(tmp_path / "t.json")
    kernel_bench.emit(rows, out)
    doc = check_bench.load(out)
    by_op = {r["op"]: r for r in doc["rows"]}
    assert by_op["pfels_transmit_fused_pallas"]["pinned"]
    assert by_op["pfels_transmit_fused_pallas"]["oracle_us_per_call"] \
        == 80.0
    assert not by_op["pfels_transmit_unfused"]["pinned"]

    with pytest.raises(ValueError, match="oracle"):
        kernel_bench.emit(rows[:1], str(tmp_path / "t2.json"))


def test_schema_versions_in_lockstep():
    from benchmarks import kernel_bench
    assert kernel_bench.SCHEMA_VERSION == check_bench.SCHEMA_VERSION


def test_time_uses_perf_counter_and_floors_warmup():
    """_time must never time the compile call: even warmup=0 burns one
    untimed call first, and timings come from the monotonic clock."""
    from benchmarks import kernel_bench

    calls = []
    us = kernel_bench._time(lambda: calls.append(1), reps=3, warmup=0)
    assert us >= 0.0
    assert len(calls) == 4  # 1 floored warmup + 3 timed
    calls.clear()
    kernel_bench._time(lambda: calls.append(1), reps=2, warmup=3)
    assert len(calls) == 5
