import os
import sys

# tests see the default single CPU device (the 512-device override is
# dryrun.py-only, per the system design)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
