"""The HLO cost model (dry-run roofline source) vs analytic counts."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _cost(f, *args):
    c = jax.jit(f).lower(*args).compile()
    return analyze_hlo(c.as_text())


def test_single_matmul():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = _cost(lambda a, b: a @ b, x, x)
    assert r["flops"] == pytest.approx(2 * 256 ** 3, rel=0.05)


def test_scan_multiplies_trip_count():
    def g(a, b):
        def body(x, _):
            return x @ b, None
        y, _ = jax.lax.scan(body, a, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = _cost(g, x, x)
    assert r["flops"] == pytest.approx(10 * 2 * 256 ** 3, rel=0.05)


def test_nested_scans():
    def h(a, b):
        def outer(x, _):
            def inner(y, _):
                return y @ b, None
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, a, None, length=4)
        return y
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = _cost(h, x, x)
    assert r["flops"] == pytest.approx(20 * 2 * 256 ** 3, rel=0.05)


def test_grad_of_scan_counts_backward():
    def loss(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return jnp.sum(h ** 2)
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = _cost(jax.grad(loss), x, x)
    # fwd + 2 bwd matmuls per layer = 3x
    assert r["flops"] >= 0.9 * 3 * 8 * 2 * 256 ** 3


def test_bytes_nonzero_and_scaled_by_loop():
    def g(a):
        def body(x, _):
            return x + 1.0, None
        y, _ = jax.lax.scan(body, a, None, length=50)
        return y
    x = jax.ShapeDtypeStruct((1024, 128), jnp.float32)
    r = _cost(g, x)
    # ~50 iterations x (read + write) x 512KiB
    assert r["bytes"] >= 50 * 2 * 1024 * 128 * 4 * 0.9
