"""Full FL rounds (simulation mode): all algorithms run and PFELS learns."""

import jax
import jax.numpy as jnp
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs import PFELSConfig
from repro.configs.paper_models import BENCH_MLP
from repro.data import make_federated_classification
from repro.fl import evaluate, make_round_fn, setup
from repro.models import cnn


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    params = cnn.init_cnn(key, BENCH_MLP)
    flat, unravel = ravel_pytree(params)
    x, y, xt, yt = make_federated_classification(
        key, n_clients=30, per_client=30, num_classes=10,
        image_shape=(1, 8, 8))
    loss_fn = lambda p, b: cnn.cnn_loss(p, BENCH_MLP, b)
    return params, flat.shape[0], unravel, (x, y, xt, yt), loss_fn


@pytest.mark.parametrize("alg", ["pfels", "wfl_p", "wfl_pdp", "dp_fedavg",
                                 "fedavg"])
def test_all_algorithms_run(problem, alg):
    params, d, unravel, (x, y, xt, yt), loss_fn = problem
    cfg = PFELSConfig(num_clients=30, clients_per_round=4, local_steps=3,
                      local_lr=0.05, compression_ratio=0.3, epsilon=2.0,
                      rounds=2, algorithm=alg)
    state = setup(jax.random.PRNGKey(1), params, cfg, d)
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    p, m = fn(params, state.power_limits, x, y, jax.random.PRNGKey(2))
    assert jnp.isfinite(m["train_loss"])
    assert not any(bool(jnp.any(jnp.isnan(l))) for l in jax.tree.leaves(p))
    if alg in ("pfels", "wfl_p", "wfl_pdp"):
        assert float(m["energy"]) > 0
    if alg == "pfels":
        assert int(m["subcarriers"]) == int(round(0.3 * d))
    else:
        assert int(m["subcarriers"]) in (d,)


@pytest.mark.slow
def test_pfels_learns(problem):
    params, d, unravel, (x, y, xt, yt), loss_fn = problem
    cfg = PFELSConfig(num_clients=30, clients_per_round=8, local_steps=5,
                      local_lr=0.05, compression_ratio=0.3, epsilon=2.0,
                      rounds=25, momentum=0.9)
    state = setup(jax.random.PRNGKey(1), params, cfg, d)
    fn = make_round_fn(cfg, loss_fn, d, unravel)
    _, acc0 = evaluate(params, loss_fn, xt, yt)
    p = params
    for t in range(cfg.rounds):
        p, m = fn(p, state.power_limits, x, y, jax.random.PRNGKey(100 + t))
    _, acc1 = evaluate(p, loss_fn, xt, yt)
    assert acc1 > acc0 + 0.2, (acc0, acc1)
